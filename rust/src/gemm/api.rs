//! The co-design GEMM API: the paper's proposal made concrete.
//!
//! A [`GemmEngine`] owns an architecture description, the registry of
//! runnable micro-kernels, a sequential workspace, an optional persistent
//! [`WorkerPool`] (parallel plans) and a **config-selection memoization
//! cache**. Its [`ConfigMode`] selects the paper's compared policies:
//!
//! - [`ConfigMode::BlisStatic`] — baseline R1: a single stock micro-kernel
//!   and CCPs fixed per architecture, only clamped by the dimensions.
//! - [`ConfigMode::OriginalModel`] — Low-et-al. CCPs, shape-independent.
//! - [`ConfigMode::Refined`] — the contribution: per-call dynamic
//!   selection of micro-kernel + CCPs from the refined dimension-aware
//!   model (§3.3/§3.4).
//! - [`ConfigMode::Fixed`] — pin an explicit configuration (used by the
//!   experiment harness to reproduce a specific paper variant).
//!
//! # Memoized selection
//!
//! Blocked LU/Cholesky/QR call the engine once per panel step with a
//! small set of recurring shapes (`m = n` shrinking, `k = b`), and a
//! serving coordinator sees the same request shapes over and over. The
//! engine therefore memoizes [`GemmEngine::plan_config`] on
//! `(mode, GemmDims)`: the analytical/refined scorer runs once per
//! distinct shape, and every later call is a hash lookup.
//! [`GemmEngine::config_cache_stats`] exposes hit/miss counts so tests
//! and benches can assert the accounting.
//!
//! # Threading
//!
//! [`GemmEngine::with_plan`] provisions a persistent worker pool sized to
//! the plan — created **once**, reused by every subsequent GEMM (and by a
//! whole LU/Cholesky/QR factorization sweep). Pools can also be shared
//! between engines ([`GemmEngine::set_shared_pool`]); the coordinator
//! server uses that to run all request workers against one machine-wide
//! team.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::Arc;

use crate::arch::Arch;
use crate::model::ccp::GemmConfig;
use crate::model::selector::{select_from, AnalyticScorer};
use crate::model::{blis_static, original_ccp, refined_ccp, GemmDims, MicroKernel};
use crate::runtime::pool::WorkerPool;
use crate::util::matrix::{MatView, MatViewMut};

use super::blocked::{gemm_blocked, Workspace};
use super::microkernel::{for_shape, registry, MicroKernelImpl};
use super::parallel::{gemm_parallel, ThreadPlan};

/// Configuration policy for the engine.
#[derive(Clone, Debug)]
pub enum ConfigMode {
    /// BLIS-like baseline: static CCPs + single stock micro-kernel.
    BlisStatic,
    /// Original analytical model (shape-independent CCPs), stock kernel.
    OriginalModel,
    /// The paper's refined dimension-aware model with dynamic
    /// micro-kernel selection over the runnable family.
    Refined,
    /// Refined CCPs for one pinned micro-kernel shape.
    RefinedWithKernel(MicroKernel),
    /// Fully pinned configuration.
    Fixed(GemmConfig),
}

/// Hashable fingerprint of a [`ConfigMode`] used as part of the memo key,
/// so mutating `engine.mode` can never serve a stale selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum ModeKey {
    Blis,
    Original,
    Refined,
    RefinedWith(MicroKernel),
    Fixed(GemmConfig),
}

fn mode_key(mode: &ConfigMode) -> ModeKey {
    match mode {
        ConfigMode::BlisStatic => ModeKey::Blis,
        ConfigMode::OriginalModel => ModeKey::Original,
        ConfigMode::Refined => ModeKey::Refined,
        ConfigMode::RefinedWithKernel(mk) => ModeKey::RefinedWith(*mk),
        ConfigMode::Fixed(cfg) => ModeKey::Fixed(*cfg),
    }
}

/// Hit/miss accounting of the config-selection memo cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ConfigCacheStats {
    pub hits: u64,
    pub misses: u64,
}

/// The engine: arch + kernels + workspace + pool + policy.
pub struct GemmEngine {
    pub arch: Arch,
    pub mode: ConfigMode,
    pub plan: ThreadPlan,
    kernels: Vec<MicroKernelImpl>,
    /// Workspace for the sequential path (parallel paths use the pool's
    /// per-worker pinned workspaces).
    workspace: Workspace,
    /// Persistent worker team; `None` until a parallel plan is set.
    pool: Option<Arc<WorkerPool>>,
    /// Memoized `(mode, dims) -> config` selections.
    config_cache: RefCell<HashMap<(ModeKey, GemmDims), GemmConfig>>,
    cache_stats: Cell<ConfigCacheStats>,
    /// Last configuration chosen (introspection for tests/harness).
    pub last_config: Option<GemmConfig>,
}

impl GemmEngine {
    /// Engine with every kernel runnable on this host.
    pub fn new(arch: Arch, mode: ConfigMode) -> Self {
        Self::with_kernels(arch, mode, registry())
    }

    /// Engine restricted to an explicit kernel set.
    pub fn with_kernels(arch: Arch, mode: ConfigMode, kernels: Vec<MicroKernelImpl>) -> Self {
        assert!(!kernels.is_empty(), "no micro-kernels available");
        Self {
            arch,
            mode,
            plan: ThreadPlan::sequential(),
            kernels,
            workspace: Workspace::new(),
            pool: None,
            config_cache: RefCell::new(HashMap::new()),
            cache_stats: Cell::new(ConfigCacheStats::default()),
            last_config: None,
        }
    }

    /// Set the threading plan. A persistent worker pool is provisioned
    /// once (and re-provisioned only if the thread count changes); every
    /// subsequent GEMM reuses it with zero thread spawns.
    pub fn with_plan(mut self, plan: ThreadPlan) -> Self {
        let need_new = plan.threads > 1
            && match &self.pool {
                Some(p) => p.threads() != plan.threads,
                None => true,
            };
        if need_new {
            self.pool = Some(Arc::new(WorkerPool::new(plan.threads)));
        }
        self.plan = plan;
        self
    }

    /// Adopt an externally owned pool (e.g. one team shared by every
    /// worker of the coordinator server). The plan's thread count is
    /// aligned with the pool's.
    pub fn set_shared_pool(&mut self, pool: Arc<WorkerPool>) {
        self.plan = ThreadPlan { threads: pool.threads(), target: self.plan.target };
        self.pool = Some(pool);
    }

    /// The persistent pool, if a parallel plan was provisioned.
    pub fn pool(&self) -> Option<&Arc<WorkerPool>> {
        self.pool.as_ref()
    }

    /// The micro-kernel shapes eligible for *dynamic selection*: prefetch
    /// variants are explicit choices, and when SIMD implementations exist
    /// the scalar fallbacks are excluded — the analytical scorer ranks
    /// shapes by cache behaviour and register-file arithmetic, which only
    /// compares like-for-like implementations (a scalar 8x8 would rank
    /// well on paper and run an order of magnitude slower).
    pub fn family(&self) -> Vec<MicroKernel> {
        let any_simd = self.kernels.iter().any(|k| k.simd);
        let mut f: Vec<MicroKernel> = self
            .kernels
            .iter()
            .filter(|k| !k.prefetch && (!any_simd || k.simd))
            .map(|k| k.spec)
            .collect();
        f.sort();
        f.dedup();
        f
    }

    fn implementation_for(&self, spec: MicroKernel) -> MicroKernelImpl {
        self.kernels
            .iter()
            .find(|k| k.spec == spec && !k.prefetch)
            .copied()
            .or_else(|| for_shape(spec))
            .unwrap_or_else(|| panic!("no implementation for {spec}"))
    }

    /// Run the configured selection policy for `dims` (uncached).
    fn compute_config(&self, dims: GemmDims) -> GemmConfig {
        match &self.mode {
            ConfigMode::BlisStatic => {
                let cfg = blis_static(&self.arch.name)
                    .expect("no BLIS static preset for this architecture");
                GemmConfig { mk: cfg.mk, ccp: cfg.ccp.clamp_to(dims) }
            }
            ConfigMode::OriginalModel => {
                let mk = blis_static(&self.arch.name).map(|c| c.mk).unwrap_or(MicroKernel::new(8, 6));
                GemmConfig { mk, ccp: original_ccp(&self.arch, mk).clamp_to(dims) }
            }
            ConfigMode::Refined => {
                select_from(&self.arch, dims, &AnalyticScorer, &self.family()).config
            }
            ConfigMode::RefinedWithKernel(mk) => {
                GemmConfig { mk: *mk, ccp: refined_ccp(&self.arch, *mk, dims).clamp_to(dims) }
            }
            ConfigMode::Fixed(cfg) => GemmConfig { mk: cfg.mk, ccp: cfg.ccp.clamp_to(dims) },
        }
    }

    /// Upper bound on memoized selections: a long-lived server engine fed
    /// ever-changing shapes must not grow without bound. On overflow the
    /// whole map is reset (an epoch flush is simpler than LRU and the
    /// recurring-shape workloads this cache targets refill it in a few
    /// misses); stats keep accumulating across flushes.
    const CONFIG_CACHE_CAP: usize = 4096;

    /// Resolve the configuration this engine would use for `dims`,
    /// memoized on `(mode, dims)` — repeated shapes (an LU trailing-update
    /// sweep, a steady request mix) skip the scorer entirely.
    pub fn plan_config(&self, dims: GemmDims) -> GemmConfig {
        let key = (mode_key(&self.mode), dims);
        if let Some(cfg) = self.config_cache.borrow().get(&key) {
            let mut s = self.cache_stats.get();
            s.hits += 1;
            self.cache_stats.set(s);
            return *cfg;
        }
        let cfg = self.compute_config(dims);
        {
            let mut cache = self.config_cache.borrow_mut();
            if cache.len() >= Self::CONFIG_CACHE_CAP {
                cache.clear();
            }
            cache.insert(key, cfg);
        }
        let mut s = self.cache_stats.get();
        s.misses += 1;
        self.cache_stats.set(s);
        cfg
    }

    /// Memo-cache accounting (hits/misses of [`Self::plan_config`]).
    pub fn config_cache_stats(&self) -> ConfigCacheStats {
        self.cache_stats.get()
    }

    /// Number of selections currently memoized (bounded by the cap).
    pub fn config_cache_len(&self) -> usize {
        self.config_cache.borrow().len()
    }

    /// Drop all memoized selections and reset the accounting.
    pub fn clear_config_cache(&mut self) {
        self.config_cache.borrow_mut().clear();
        self.cache_stats.set(ConfigCacheStats::default());
    }

    /// Dispatch one configured GEMM to the pool-parallel or sequential
    /// blocked driver.
    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &mut self,
        cfg: &GemmConfig,
        kernel: &MicroKernelImpl,
        alpha: f64,
        a: MatView<'_>,
        b: MatView<'_>,
        beta: f64,
        c: &mut MatViewMut<'_>,
    ) {
        match &self.pool {
            Some(pool) if self.plan.threads > 1 => {
                gemm_parallel(cfg, kernel, alpha, a, b, beta, c, self.plan.target, pool);
            }
            _ => gemm_blocked(cfg, kernel, alpha, a, b, beta, c, &mut self.workspace),
        }
    }

    /// `C = alpha * A * B + beta * C`.
    pub fn gemm(
        &mut self,
        alpha: f64,
        a: MatView<'_>,
        b: MatView<'_>,
        beta: f64,
        c: &mut MatViewMut<'_>,
    ) {
        let dims = GemmDims::new(a.rows, b.cols, a.cols);
        let cfg = self.plan_config(dims);
        let kernel = self.implementation_for(cfg.mk);
        self.last_config = Some(cfg);
        self.dispatch(&cfg, &kernel, alpha, a, b, beta, c);
    }

    /// Run with an explicit configuration, bypassing the policy (used by
    /// the experiment harness).
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_with_config(
        &mut self,
        cfg: &GemmConfig,
        alpha: f64,
        a: MatView<'_>,
        b: MatView<'_>,
        beta: f64,
        c: &mut MatViewMut<'_>,
    ) {
        let kernel = self.implementation_for(cfg.mk);
        self.last_config = Some(*cfg);
        self.dispatch(cfg, &kernel, alpha, a, b, beta, c);
    }

    /// Run with an explicit named kernel (including prefetch variants).
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_with_kernel_name(
        &mut self,
        name: &str,
        ccp: crate::model::Ccp,
        alpha: f64,
        a: MatView<'_>,
        b: MatView<'_>,
        beta: f64,
        c: &mut MatViewMut<'_>,
    ) {
        let kernel = self
            .kernels
            .iter()
            .find(|k| k.name == name)
            .copied()
            .unwrap_or_else(|| panic!("kernel {name} not registered"));
        let dims = GemmDims::new(a.rows, b.cols, a.cols);
        let cfg = GemmConfig { mk: kernel.spec, ccp: ccp.clamp_to(dims) };
        self.last_config = Some(cfg);
        gemm_blocked(&cfg, &kernel, alpha, a, b, beta, c, &mut self.workspace);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{carmel, epyc7282, host_xeon};
    use crate::gemm::gemm_reference;
    use crate::util::{MatrixF64, Pcg64};

    fn check_engine(mut eng: GemmEngine, m: usize, n: usize, k: usize) -> GemmConfig {
        let mut rng = Pcg64::seed(77);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let mut c = MatrixF64::random(m, n, &mut rng);
        let mut expect = c.clone();
        gemm_reference(1.5, a.view(), b.view(), 0.5, &mut expect.view_mut());
        eng.gemm(1.5, a.view(), b.view(), 0.5, &mut c.view_mut());
        assert!(c.max_abs_diff(&expect) < 1e-12 * k as f64, "engine mode {:?}", eng.mode);
        eng.last_config.unwrap()
    }

    #[test]
    fn all_modes_correct() {
        for mode in [
            ConfigMode::BlisStatic,
            ConfigMode::OriginalModel,
            ConfigMode::Refined,
            ConfigMode::RefinedWithKernel(MicroKernel::new(12, 4)),
        ] {
            check_engine(GemmEngine::new(carmel(), mode), 70, 50, 30);
        }
    }

    #[test]
    fn refined_mode_adapts_ccp_to_k() {
        let eng = GemmEngine::new(epyc7282(), ConfigMode::Refined);
        let skinny = eng.plan_config(GemmDims::new(2000, 2000, 64));
        let fat = eng.plan_config(GemmDims::new(2000, 2000, 2000));
        assert!(skinny.ccp.mc > fat.ccp.mc, "refined mc must grow as k shrinks");
        assert_eq!(skinny.ccp.kc, 64);
    }

    #[test]
    fn blis_static_mode_pins_ccp() {
        let eng = GemmEngine::new(carmel(), ConfigMode::BlisStatic);
        let cfg = eng.plan_config(GemmDims::new(2000, 2000, 128));
        assert_eq!(cfg.ccp, crate::model::Ccp::new(120, 2000, 128));
        assert_eq!(cfg.mk, MicroKernel::new(6, 8));
    }

    #[test]
    fn parallel_engine_correct_and_pool_persistent() {
        let eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads: 3, target: crate::gemm::ParallelLoop::G4 });
        let pool = Arc::clone(eng.pool().expect("parallel plan provisions a pool"));
        check_engine(eng, 90, 70, 40);
        assert_eq!(pool.threads(), 3);
        assert_eq!(pool.spawned_workers(), 2, "exactly threads-1 workers, spawned once");
    }

    #[test]
    fn with_plan_keeps_existing_pool_for_same_width() {
        let eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads: 3, target: crate::gemm::ParallelLoop::G4 });
        let first = Arc::clone(eng.pool().unwrap());
        let eng = eng.with_plan(ThreadPlan { threads: 3, target: crate::gemm::ParallelLoop::G3 });
        assert!(Arc::ptr_eq(&first, eng.pool().unwrap()), "same width must reuse the pool");
    }

    #[test]
    fn config_cache_hits_and_misses_are_accounted() {
        let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
        let dims = GemmDims::new(120, 120, 24);
        let first = eng.plan_config(dims);
        assert_eq!(eng.config_cache_stats(), ConfigCacheStats { hits: 0, misses: 1 });
        for _ in 0..4 {
            assert_eq!(eng.plan_config(dims), first, "cached selection must be stable");
        }
        assert_eq!(eng.config_cache_stats(), ConfigCacheStats { hits: 4, misses: 1 });
        // A different shape is a fresh miss...
        eng.plan_config(GemmDims::new(60, 60, 24));
        assert_eq!(eng.config_cache_stats(), ConfigCacheStats { hits: 4, misses: 2 });
        // ...and so is the same shape under a different mode (stale-mode
        // entries must never be served).
        eng.mode = ConfigMode::BlisStatic;
        let blis = eng.plan_config(dims);
        assert_eq!(eng.config_cache_stats(), ConfigCacheStats { hits: 4, misses: 3 });
        assert_ne!(blis, first);
        eng.clear_config_cache();
        assert_eq!(eng.config_cache_stats(), ConfigCacheStats::default());
    }

    #[test]
    fn config_cache_is_bounded() {
        // A server engine fed ever-changing shapes must not grow without
        // bound: the map flushes at the cap, stats keep counting.
        let eng =
            GemmEngine::new(host_xeon(), ConfigMode::RefinedWithKernel(MicroKernel::new(8, 6)));
        let n = GemmEngine::CONFIG_CACHE_CAP + 100;
        for i in 0..n {
            eng.plan_config(GemmDims::new(8 + i, 8, 8));
        }
        assert!(eng.config_cache_len() <= GemmEngine::CONFIG_CACHE_CAP);
        assert_eq!(eng.config_cache_stats().misses, n as u64);
    }

    #[test]
    fn engine_family_nonempty_and_deduped() {
        let eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
        let fam = eng.family();
        assert!(!fam.is_empty());
        let mut f2 = fam.clone();
        f2.dedup();
        assert_eq!(fam, f2);
    }
}
