//! The co-design GEMM API: the paper's proposal made concrete.
//!
//! A [`GemmEngine`] owns an architecture description, the registry of
//! runnable micro-kernels, a sequential workspace, an optional persistent
//! [`WorkerPool`] (parallel plans) and a **config-selection memoization
//! cache**. Its [`ConfigMode`] selects the paper's compared policies:
//!
//! - [`ConfigMode::BlisStatic`] — baseline R1: a single stock micro-kernel
//!   and CCPs fixed per architecture, only clamped by the dimensions.
//! - [`ConfigMode::OriginalModel`] — Low-et-al. CCPs, shape-independent.
//! - [`ConfigMode::Refined`] — the contribution: per-call dynamic
//!   selection of micro-kernel + CCPs from the refined dimension-aware
//!   model (§3.3/§3.4).
//! - [`ConfigMode::Fixed`] — pin an explicit configuration (used by the
//!   experiment harness to reproduce a specific paper variant).
//!
//! # Memoized selection
//!
//! Blocked LU/Cholesky/QR call the engine once per panel step with a
//! small set of recurring shapes (`m = n` shrinking, `k = b`), and a
//! serving coordinator sees the same request shapes over and over. The
//! engine therefore memoizes [`GemmEngine::plan_config`] on
//! `(mode, GemmDims)`: the analytical/refined scorer runs once per
//! distinct shape, and every later call is a hash lookup.
//! [`GemmEngine::config_cache_stats`] exposes hit/miss counts so tests
//! and benches can assert the accounting.
//!
//! # Threading
//!
//! [`GemmEngine::with_plan`] provisions a persistent worker pool sized to
//! the plan — created **once**, reused by every subsequent GEMM (and by a
//! whole LU/Cholesky/QR factorization sweep). Pools can also be shared
//! between engines ([`GemmEngine::set_shared_pool`]); the coordinator
//! server uses that to run all request workers against one machine-wide
//! team.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::arch::Arch;
use crate::model::batchplan::BatchPlanner;
use crate::model::ccp::GemmConfig;
use crate::model::profile::PerfProfile;
use crate::model::selector::{select_from_elem, AnalyticScorer, Scorer};
use crate::model::teamsize::{PanelShape, TeamSizeSelector, TeamSizeStats};
use crate::model::{blis_static_dt, original_ccp_elem, refined_ccp_elem, GemmDims, MicroKernel};
use crate::runtime::pool::{SubTeam, WorkerPool};
use crate::util::elem::{DType, Elem};
use crate::util::matrix::{MatView, MatViewMut};

use crate::util::error::DlaError;

use super::abft::{gemm_blocked_abft, AbftCtx, AbftStats, VerifyPolicy};
use super::blocked::{gemm_blocked, Workspace};
use super::microkernel::{for_shape, for_shape_f32, registry, registry_f32, MicroKernelImpl};
use super::parallel::{
    gemm_batch_parallel, gemm_fused_trailing_ranges_abft, gemm_fused_trailing_ranges_seq,
    gemm_parallel_abft, BatchGemm, ThreadPlan,
};

/// An element type the [`GemmEngine`] can drive end to end: ties an
/// [`Elem`] to its host micro-kernel registry and to the engine's
/// per-dtype kernel set. The engine's generic entry points
/// ([`GemmEngine::gemm_t`], [`GemmEngine::gemm_fused_trailing_ranges_t`],
/// [`GemmEngine::gemm_batch_t`], …) are bounded by this; `f64` and `f32`
/// are the provided instantiations.
pub trait GemmElem: Elem {
    /// The host registry of runnable kernels for this element type
    /// (memoized; see [`crate::gemm::microkernel`]).
    fn host_kernel(spec: MicroKernel) -> Option<MicroKernelImpl<Self>>;
    /// The engine's registered kernel set for this element type.
    fn engine_kernels(engine: &GemmEngine) -> &[MicroKernelImpl<Self>];
}

impl GemmElem for f64 {
    fn host_kernel(spec: MicroKernel) -> Option<MicroKernelImpl<f64>> {
        for_shape(spec)
    }

    fn engine_kernels(engine: &GemmEngine) -> &[MicroKernelImpl<f64>] {
        &engine.kernels
    }
}

impl GemmElem for f32 {
    fn host_kernel(spec: MicroKernel) -> Option<MicroKernelImpl<f32>> {
        for_shape_f32(spec)
    }

    fn engine_kernels(engine: &GemmEngine) -> &[MicroKernelImpl<f32>] {
        &engine.kernels_f32
    }
}

/// Lookahead policy for the blocked factorization drivers: while the
/// update sub-team finishes a trailing update, a panel sub-team factors
/// the next panel(s) inside the freshly-updated columns
/// ([`GemmEngine::gemm_fused_trailing_ranges`]).
///
/// `depth == 0` disables lookahead (construct via [`Lookahead::disabled`]).
/// `depth >= 1` is honored by all three drivers: the work-queue pipeline
/// keeps up to `depth` panels factored ahead of the trailing sweep.
///
/// `panel_workers == 0` (the [`AUTO_PANEL_WORKERS`] sentinel, and the
/// default) means **model-driven malleable** `t_p`: each iteration the
/// engine's [`crate::model::teamsize::TeamSizeSelector`] balances the
/// panel critical path against the trailing sweep and resizes the panel
/// sub-team. A non-zero value pins `t_p` for every iteration
/// (`DLA_PANEL_WORKERS` also accepts a comma-separated per-iteration
/// schedule, resolved by [`GemmEngine::panel_team_size`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Lookahead {
    /// Panels factored ahead of the trailing sweep (0 = off).
    pub depth: usize,
    /// Sub-team size `t_p` dedicated to the panel factorization;
    /// [`AUTO_PANEL_WORKERS`] (0) selects it per iteration from the
    /// team-size model.
    pub panel_workers: usize,
}

/// Sentinel for [`Lookahead::panel_workers`]: let the team-size model
/// choose `t_p` per iteration.
pub const AUTO_PANEL_WORKERS: usize = 0;

/// Which execution model the blocked factorizations (LU / Cholesky / QR)
/// run on this engine:
///
/// - [`SchedPolicy::Lookahead`] (the default) — the fused fork-join
///   pipeline of PRs 2–3: per-iteration broadcast jobs with split
///   sub-teams and the deep work queue when [`Lookahead`] is enabled.
/// - [`SchedPolicy::Dag`] — the tile-DAG dataflow scheduler
///   (`runtime/dag.rs`): the factorization is decomposed into b×b tile
///   tasks with explicit dependencies and drained by the pool ranks
///   through work-stealing deques, with **no** per-iteration barriers.
///
/// Resolution mirrors [`Lookahead`]: an explicitly pinned policy always
/// wins, then the `DLA_SCHED` environment override (`dag` /
/// `lookahead`), then the default. Both paths produce bitwise-identical
/// factors (the tile decompositions replay the serialized baseline's
/// per-column op order under configs planned on the full trailing dims),
/// so flipping the knob is a pure scheduling ablation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Fork-join epochs with the (optional) fused lookahead pipeline.
    #[default]
    Lookahead,
    /// Tile-DAG dataflow over work-stealing deques.
    Dag,
}

impl SchedPolicy {
    /// Environment override: `DLA_SCHED=dag` or `DLA_SCHED=lookahead`
    /// (case-insensitive); unset or empty is ignored. Anything else
    /// falls back to the default scheduler with one warning line — a
    /// typo must fail towards the bitwise-oracle lookahead path, not
    /// silently pick a scheduler the operator did not ask for (the
    /// `DLA_BATCH` convention).
    pub fn from_env() -> Option<Self> {
        match std::env::var("DLA_SCHED").ok().as_deref().map(str::trim) {
            None | Some("") => None,
            Some(v) if v.eq_ignore_ascii_case("dag") => Some(Self::Dag),
            Some(v) if v.eq_ignore_ascii_case("lookahead") => Some(Self::Lookahead),
            Some(v) => {
                eprintln!(
                    "dla: unrecognized DLA_SCHED={v:?}; keeping the default scheduler \
                     (expected dag or lookahead)"
                );
                None
            }
        }
    }
}

impl Lookahead {
    /// Lookahead off: the factorizations serialize panel and update.
    pub fn disabled() -> Self {
        Self { depth: 0, panel_workers: AUTO_PANEL_WORKERS }
    }

    /// The default policy for a `threads`-wide team: depth-1 lookahead
    /// with model-driven malleable `t_p`.
    pub fn heuristic(threads: usize) -> Self {
        if threads < 2 {
            Self::disabled()
        } else {
            Self { depth: 1, panel_workers: AUTO_PANEL_WORKERS }
        }
    }

    pub fn enabled(&self) -> bool {
        self.depth > 0
    }

    /// Validate against a team width, with a clear error instead of a
    /// silent clamp: an enabled policy on a multi-thread plan must leave
    /// the update sub-team non-empty, and a disabled policy must not
    /// carry a panel team.
    pub fn validate(&self, threads: usize) -> Result<(), String> {
        if self.depth == 0 && self.panel_workers != AUTO_PANEL_WORKERS {
            return Err(format!(
                "Lookahead depth 0 (disabled) cannot have panel_workers = {} (use \
                 Lookahead::disabled())",
                self.panel_workers
            ));
        }
        if self.enabled() && threads > 1 && self.panel_workers >= threads {
            return Err(format!(
                "Lookahead panel_workers = {} would leave no update ranks on a {}-thread \
                 plan (need panel_workers < threads, or 0 for model-driven sizing)",
                self.panel_workers, threads
            ));
        }
        Ok(())
    }

    /// Environment override for the ablation harness: `DLA_LOOKAHEAD`
    /// (`0`/`off`/`false` disable, a number sets the depth, anything else
    /// enables depth 1; unset or empty is ignored) and
    /// `DLA_PANEL_WORKERS` (a single number pins `t_p`; a comma-separated
    /// schedule is handled by [`GemmEngine::panel_team_size`] and leaves
    /// the policy on model-driven sizing here). Returns `None` when
    /// neither variable is set.
    pub fn from_env(threads: usize) -> Option<Self> {
        let depth_var = std::env::var("DLA_LOOKAHEAD").ok();
        let tp = std::env::var("DLA_PANEL_WORKERS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&t| t > 0);
        let base = match depth_var.as_deref().map(str::trim) {
            None | Some("") => None,
            Some("0") | Some("off") | Some("false") => Some(Self::disabled()),
            Some(v) => {
                let depth = v.parse::<usize>().unwrap_or(1).max(1);
                Some(Self { depth, panel_workers: AUTO_PANEL_WORKERS })
            }
        };
        match (base, tp) {
            (Some(la), Some(t)) if la.enabled() => Some(Self { panel_workers: t, ..la }),
            (Some(la), _) => Some(la),
            (None, Some(t)) => {
                let h = Self::heuristic(threads);
                h.enabled().then_some(Self { panel_workers: t, ..h })
            }
            (None, None) => None,
        }
    }
}

/// One item of a batched GEMM call ([`GemmEngine::gemm_batch`] /
/// [`GemmEngine::gemm_batch_t`]):
/// `C = alpha * A * B + beta * C`, independent of every other item.
pub struct GemmBatchItem<'a, E = f64> {
    pub alpha: E,
    pub a: MatView<'a, E>,
    pub b: MatView<'a, E>,
    pub beta: E,
    pub c: MatViewMut<'a, E>,
}

/// Configuration policy for the engine.
#[derive(Clone, Debug)]
pub enum ConfigMode {
    /// BLIS-like baseline: static CCPs + single stock micro-kernel.
    BlisStatic,
    /// Original analytical model (shape-independent CCPs), stock kernel.
    OriginalModel,
    /// The paper's refined dimension-aware model with dynamic
    /// micro-kernel selection over the runnable family.
    Refined,
    /// Refined CCPs for one pinned micro-kernel shape.
    RefinedWithKernel(MicroKernel),
    /// Fully pinned configuration.
    Fixed(GemmConfig),
}

/// Hashable fingerprint of a [`ConfigMode`] used as part of the memo key,
/// so mutating `engine.mode` can never serve a stale selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum ModeKey {
    Blis,
    Original,
    Refined,
    RefinedWith(MicroKernel),
    Fixed(GemmConfig),
}

fn mode_key(mode: &ConfigMode) -> ModeKey {
    match mode {
        ConfigMode::BlisStatic => ModeKey::Blis,
        ConfigMode::OriginalModel => ModeKey::Original,
        ConfigMode::Refined => ModeKey::Refined,
        ConfigMode::RefinedWithKernel(mk) => ModeKey::RefinedWith(*mk),
        ConfigMode::Fixed(cfg) => ModeKey::Fixed(*cfg),
    }
}

/// Hit/miss accounting of the config-selection memo cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ConfigCacheStats {
    pub hits: u64,
    pub misses: u64,
}

/// The engine: arch + kernels + workspace + pool + policy.
pub struct GemmEngine {
    pub arch: Arch,
    pub mode: ConfigMode,
    pub plan: ThreadPlan,
    kernels: Vec<MicroKernelImpl>,
    /// Workspace for the sequential path (parallel paths use the pool's
    /// per-worker pinned workspaces).
    workspace: Workspace,
    /// Persistent worker team; `None` until a parallel plan is set.
    pool: Option<Arc<WorkerPool>>,
    /// Explicitly pinned lookahead policy (always wins); `None` = the
    /// environment override, else the heuristic for the plan width
    /// (resolved by [`Self::lookahead`]).
    lookahead: Option<Lookahead>,
    /// Explicitly pinned factorization scheduler (always wins); `None` =
    /// the `DLA_SCHED` environment override, else the default
    /// (resolved by [`Self::sched`]).
    sched: Option<SchedPolicy>,
    /// Host kernel set for the f32 path (never restricted by
    /// [`Self::with_kernels`], which pins the f64 family for the
    /// experiment harness).
    kernels_f32: Vec<MicroKernelImpl<f32>>,
    /// ABFT verification policy for every GEMM this engine dispatches.
    /// Defaults to `Off`; deliberately **not** read from the environment
    /// here — only the coordinator's `ServerConfig` resolves
    /// `DLA_VERIFY`, so an armed CI leg cannot flip bare engines in
    /// unrelated suites into verified mode.
    verify: VerifyPolicy,
    /// Shared ABFT accounting (counters + the pending typed failure);
    /// `Arc` so the coordinator can merge counters after the engine
    /// moved into a worker thread.
    abft: Arc<AbftStats>,
    /// Memoized `(mode, dtype, dims, verified, generation) -> config`
    /// selections (verified configs shave one granule off mc/nc for the
    /// checksum storage, so they memoize separately; the generation is
    /// the attached profile's memo-invalidation epoch, constant 0 when
    /// calibration is off).
    config_cache: RefCell<HashMap<(ModeKey, DType, GemmDims, bool, u64), GemmConfig>>,
    cache_stats: Cell<ConfigCacheStats>,
    /// Shared measurement store when calibration is on. `None` (the
    /// default) keeps every selection purely analytic — bitwise
    /// identical to the uncalibrated engine, no timing hooks.
    profile: Option<Arc<PerfProfile>>,
    /// May epsilon-exploration fire? Server worker loops clear this per
    /// Interactive-tier request (latency-critical callers must never be
    /// handed a deliberately sub-optimal trial config).
    explore_allowed: Cell<bool>,
    /// Deterministic exploration tick: every `EXPLORE_PERIOD`-th
    /// calibrated re-selection tries the runner-up candidate instead of
    /// the blended best (no RNG — reproducible in tests).
    explore_tick: Cell<u64>,
    /// Warm-state tracker: dtype + k of the most recently planned GEMM.
    /// A consecutive plan with the same k means the k-panel is resident
    /// across pipeline iterations (the lookahead/DAG trailing sweeps
    /// re-use one packed panel layout), so the analytic prior drops the
    /// A-pack cost (the Peise-style sequence discount).
    last_planned_k: Cell<Option<(DType, usize)>>,
    /// Memoized panel-team-size selections (the malleable `t_p` model).
    team_sizer: TeamSizeSelector,
    /// Memoized batch cost estimates (team shares for fused batches).
    batch_planner: BatchPlanner,
    /// Per-iteration `t_p` schedule from a comma-separated
    /// `DLA_PANEL_WORKERS` (test/ablation hook); the last entry repeats.
    panel_schedule: Option<Vec<usize>>,
    /// Last configuration chosen (introspection for tests/harness).
    pub last_config: Option<GemmConfig>,
}

impl GemmEngine {
    /// Engine with every kernel runnable on this host.
    pub fn new(arch: Arch, mode: ConfigMode) -> Self {
        Self::with_kernels(arch, mode, registry())
    }

    /// Engine restricted to an explicit kernel set.
    pub fn with_kernels(arch: Arch, mode: ConfigMode, kernels: Vec<MicroKernelImpl>) -> Self {
        assert!(!kernels.is_empty(), "no micro-kernels available");
        // A comma-separated DLA_PANEL_WORKERS is a per-iteration t_p
        // schedule (the malleability test hook); a single number is a
        // pinned t_p handled by Lookahead::from_env.
        let panel_schedule = std::env::var("DLA_PANEL_WORKERS")
            .ok()
            .filter(|v| v.contains(','))
            .map(|v| {
                v.split(',')
                    .filter_map(|t| t.trim().parse::<usize>().ok())
                    .map(|t| t.max(1))
                    .collect::<Vec<usize>>()
            })
            .filter(|v| !v.is_empty());
        Self {
            arch,
            mode,
            plan: ThreadPlan::sequential(),
            kernels,
            kernels_f32: registry_f32(),
            workspace: Workspace::new(),
            pool: None,
            lookahead: None,
            sched: None,
            verify: VerifyPolicy::Off,
            abft: Arc::new(AbftStats::new()),
            config_cache: RefCell::new(HashMap::new()),
            cache_stats: Cell::new(ConfigCacheStats::default()),
            profile: None,
            explore_allowed: Cell::new(true),
            explore_tick: Cell::new(0),
            last_planned_k: Cell::new(None),
            team_sizer: TeamSizeSelector::new(),
            batch_planner: BatchPlanner::new(),
            panel_schedule,
            last_config: None,
        }
    }

    /// Set the threading plan. A persistent worker pool is provisioned
    /// once (and re-provisioned only if the thread count changes); every
    /// subsequent GEMM reuses it with zero thread spawns.
    pub fn with_plan(mut self, plan: ThreadPlan) -> Self {
        // A pinned lookahead policy must stay valid for the new width
        // (validation would otherwise be order-dependent: pinning before
        // the plan would dodge the panel_workers < threads check).
        if let Some(la) = self.lookahead {
            if let Err(e) = la.validate(plan.threads) {
                panic!("invalid lookahead policy for the new plan: {e}");
            }
        }
        let need_new = plan.threads > 1
            && match &self.pool {
                Some(p) => p.threads() != plan.threads,
                None => true,
            };
        if need_new {
            self.pool = Some(Arc::new(WorkerPool::new(plan.threads)));
        }
        self.plan = plan;
        self
    }

    /// Adopt an externally owned pool (e.g. one team shared by every
    /// worker of the coordinator server). The plan's thread count is
    /// aligned with the pool's.
    pub fn set_shared_pool(&mut self, pool: Arc<WorkerPool>) {
        if let Some(la) = self.lookahead {
            if let Err(e) = la.validate(pool.threads()) {
                panic!("invalid lookahead policy for the shared pool: {e}");
            }
        }
        self.plan = ThreadPlan { threads: pool.threads(), target: self.plan.target };
        self.pool = Some(pool);
    }

    /// The persistent pool, if a parallel plan was provisioned.
    pub fn pool(&self) -> Option<&Arc<WorkerPool>> {
        self.pool.as_ref()
    }

    /// Pin a lookahead policy (see [`Lookahead`]); builder form. Panics
    /// on a policy that is invalid for the current plan width (depth-0
    /// with a panel team, or `panel_workers >= threads`) — the silent
    /// clamps these used to get hid real misconfigurations.
    pub fn with_lookahead(mut self, la: Lookahead) -> Self {
        self.set_lookahead(la);
        self
    }

    /// Pin a lookahead policy in place (validated; see
    /// [`Self::with_lookahead`]).
    pub fn set_lookahead(&mut self, la: Lookahead) {
        if let Err(e) = la.validate(self.plan.threads) {
            panic!("invalid lookahead policy: {e}");
        }
        self.lookahead = Some(la);
    }

    /// Pin the factorization scheduler ([`SchedPolicy`]); builder form.
    /// A pinned policy wins over the `DLA_SCHED` environment override,
    /// so an ablation arm stays on its scheduler regardless of stray
    /// environment.
    pub fn with_sched(mut self, sched: SchedPolicy) -> Self {
        self.set_sched(sched);
        self
    }

    /// Pin the factorization scheduler in place.
    pub fn set_sched(&mut self, sched: SchedPolicy) {
        self.sched = Some(sched);
    }

    /// Resolve the effective factorization scheduler: pinned policy,
    /// then the `DLA_SCHED` environment override, then the default
    /// ([`SchedPolicy::Lookahead`]) — the same resolution order as
    /// [`Self::lookahead`].
    pub fn sched(&self) -> SchedPolicy {
        if let Some(s) = self.sched {
            return s;
        }
        SchedPolicy::from_env().unwrap_or_default()
    }

    /// Pin the ABFT verification policy; builder form.
    pub fn with_verify(mut self, policy: VerifyPolicy) -> Self {
        self.set_verify(policy);
        self
    }

    /// Set the ABFT verification policy in place.
    pub fn set_verify(&mut self, policy: VerifyPolicy) {
        self.verify = policy;
    }

    /// The engine's ABFT verification policy.
    pub fn verify(&self) -> VerifyPolicy {
        self.verify
    }

    /// Attach a (shared) measurement store; builder form. Calibrated
    /// engines time their pool dispatches, blend analytic priors with
    /// the store's observations on every config re-selection, and may
    /// occasionally explore a runner-up candidate (see
    /// [`crate::model::profile`]).
    pub fn with_calibration(mut self, profile: Arc<PerfProfile>) -> Self {
        self.set_calibration(Some(profile));
        self
    }

    /// Attach or detach the measurement store in place. `None` restores
    /// the pure-analytic engine (bitwise identical selections, zero
    /// timing overhead).
    pub fn set_calibration(&mut self, profile: Option<Arc<PerfProfile>>) {
        self.batch_planner.set_profile(profile.clone());
        self.profile = profile;
        self.explore_tick.set(0);
        self.last_planned_k.set(None);
    }

    /// The attached measurement store, if calibration is on.
    pub fn profile(&self) -> Option<&Arc<PerfProfile>> {
        self.profile.as_ref()
    }

    /// Allow or forbid epsilon-exploration (forbid for Interactive-tier
    /// requests: a latency-critical caller must always get the blended
    /// best config). No-op without an attached profile.
    pub fn set_explore_allowed(&mut self, allowed: bool) {
        self.explore_allowed.set(allowed);
    }

    /// The shared ABFT accounting (counters + pending failure record).
    pub fn abft_stats(&self) -> &Arc<AbftStats> {
        &self.abft
    }

    /// Claim the pending ABFT failure, if verification recorded one, as
    /// the typed error the request must return. Call after every
    /// verified compute call — detection happens out-of-band on the pool
    /// ranks, so the compute APIs keep their signatures.
    pub fn take_abft_failure(&self) -> Option<DlaError> {
        self.abft
            .take_failure()
            .map(|(phase, tile)| DlaError::DataCorrupt { phase: phase.as_str(), tile })
    }

    /// Resolve the effective lookahead policy: an explicitly pinned
    /// policy always wins (so an ablation arm that pins
    /// `Lookahead::disabled()` stays disabled regardless of stray
    /// environment), then the environment override (`DLA_LOOKAHEAD` /
    /// `DLA_PANEL_WORKERS`, for flipping un-pinned engines from the
    /// harness), then the heuristic for the current plan width.
    pub fn lookahead(&self) -> Lookahead {
        if let Some(la) = self.lookahead {
            return la;
        }
        let threads = self.plan.threads;
        if let Some(env) = Lookahead::from_env(threads) {
            return env;
        }
        Lookahead::heuristic(threads)
    }

    /// The micro-kernel shapes eligible for *dynamic selection*: prefetch
    /// variants are explicit choices, and when SIMD implementations exist
    /// the scalar fallbacks are excluded — the analytical scorer ranks
    /// shapes by cache behaviour and register-file arithmetic, which only
    /// compares like-for-like implementations (a scalar 8x8 would rank
    /// well on paper and run an order of magnitude slower).
    pub fn family(&self) -> Vec<MicroKernel> {
        self.family_t::<f64>()
    }

    /// The selection family for an element type (`f64` respects a
    /// [`Self::with_kernels`] restriction; `f32` always uses the host
    /// registry).
    pub fn family_t<E: GemmElem>(&self) -> Vec<MicroKernel> {
        let kernels = E::engine_kernels(self);
        let any_simd = kernels.iter().any(|k| k.simd);
        let mut f: Vec<MicroKernel> = kernels
            .iter()
            .filter(|k| !k.prefetch && (!any_simd || k.simd))
            .map(|k| k.spec)
            .collect();
        f.sort();
        f.dedup();
        f
    }

    fn implementation_for(&self, spec: MicroKernel) -> MicroKernelImpl {
        self.implementation_for_t::<f64>(spec)
    }

    fn implementation_for_t<E: GemmElem>(&self, spec: MicroKernel) -> MicroKernelImpl<E> {
        E::engine_kernels(self)
            .iter()
            .find(|k| k.spec == spec && !k.prefetch)
            .copied()
            .or_else(|| E::host_kernel(spec))
            .unwrap_or_else(|| panic!("no {} implementation for {spec}", E::DTYPE))
    }

    /// Run the configured selection policy for `dims` (uncached), per
    /// element type: the CCP model and scorer count elements of
    /// `E::DTYPE.size_bytes()` bytes, so the f32 instantiation picks
    /// larger `mc`/`kc`/`nc` and double-height tiles, while the f64
    /// instantiation reproduces the historical selection exactly.
    fn compute_config<E: GemmElem>(&self, dims: GemmDims) -> GemmConfig {
        let esize = E::DTYPE.size_bytes();
        match &self.mode {
            ConfigMode::BlisStatic => {
                let cfg = blis_static_dt(&self.arch.name, E::DTYPE)
                    .expect("no BLIS static preset for this architecture");
                GemmConfig { mk: cfg.mk, ccp: cfg.ccp.clamp_to(dims) }
            }
            ConfigMode::OriginalModel => {
                let mk = blis_static_dt(&self.arch.name, E::DTYPE)
                    .map(|c| c.mk)
                    .unwrap_or(MicroKernel::new(8, 6));
                GemmConfig { mk, ccp: original_ccp_elem(&self.arch, mk, esize).clamp_to(dims) }
            }
            ConfigMode::Refined => {
                select_from_elem(&self.arch, dims, &AnalyticScorer, &self.family_t::<E>(), esize)
                    .config
            }
            // The pinned modes pin an *f64 harness* shape; a dtype whose
            // registry cannot run that shape (e.g. MK12x4 has no f32
            // twin) falls back to the width-aware dynamic selection
            // instead of panicking in implementation_for_t. The f64 path
            // always honors the pin — unknown f64 shapes keep failing
            // loudly there, exactly as before.
            ConfigMode::RefinedWithKernel(mk) => {
                if E::DTYPE == DType::F64 || self.has_impl_t::<E>(*mk) {
                    GemmConfig {
                        mk: *mk,
                        ccp: refined_ccp_elem(&self.arch, *mk, dims, esize).clamp_to(dims),
                    }
                } else {
                    select_from_elem(&self.arch, dims, &AnalyticScorer, &self.family_t::<E>(), esize)
                        .config
                }
            }
            ConfigMode::Fixed(cfg) => {
                if E::DTYPE == DType::F64 || self.has_impl_t::<E>(cfg.mk) {
                    GemmConfig { mk: cfg.mk, ccp: cfg.ccp.clamp_to(dims) }
                } else {
                    select_from_elem(&self.arch, dims, &AnalyticScorer, &self.family_t::<E>(), esize)
                        .config
                }
            }
        }
    }

    /// Does this engine have a runnable `E` implementation for `spec`
    /// (registered or host-registry)?
    fn has_impl_t<E: GemmElem>(&self, spec: MicroKernel) -> bool {
        E::engine_kernels(self).iter().any(|k| k.spec == spec && !k.prefetch)
            || E::host_kernel(spec).is_some()
    }

    /// Upper bound on memoized selections: a long-lived server engine fed
    /// ever-changing shapes must not grow without bound. On overflow the
    /// whole map is reset (an epoch flush is simpler than LRU and the
    /// recurring-shape workloads this cache targets refill it in a few
    /// misses); stats keep accumulating across flushes.
    const CONFIG_CACHE_CAP: usize = 4096;

    /// Resolve the configuration this engine would use for `dims` at
    /// FP64, memoized on `(mode, dtype, dims)` — repeated shapes (an LU
    /// trailing-update sweep, a steady request mix) skip the scorer
    /// entirely.
    pub fn plan_config(&self, dims: GemmDims) -> GemmConfig {
        self.plan_config_t::<f64>(dims)
    }

    /// Calibrated re-selection period: every N-th cache-missing
    /// re-selection on an explore-allowed engine dispatches the blended
    /// runner-up instead of the best, feeding the store measurements of
    /// nearby candidates it would otherwise never see. Deterministic
    /// (a tick counter, no RNG) and bounded: at most 1-in-N dispatches,
    /// never memoized, never on Interactive-tier requests.
    const EXPLORE_PERIOD: u64 = 16;

    /// The calibrated replacement for [`Self::compute_config`] on the
    /// [`ConfigMode::Refined`] path: re-rank the scorer's candidate list
    /// by the profile's confidence-weighted blend of (warm-discounted)
    /// analytic prior and measured GFLOPS, optionally exploring the
    /// runner-up. Returns `(config, explored)`; explored selections are
    /// never memoized. Every other mode — and every engine without a
    /// profile — takes the pure-analytic path unchanged.
    fn compute_config_calibrated<E: GemmElem>(&self, dims: GemmDims) -> (GemmConfig, bool) {
        let profile = match (&self.profile, &self.mode) {
            (Some(p), ConfigMode::Refined) => Arc::clone(p),
            _ => return (self.compute_config::<E>(dims), false),
        };
        let esize = E::DTYPE.size_bytes();
        let sel = select_from_elem(&self.arch, dims, &AnalyticScorer, &self.family_t::<E>(), esize);
        let warm = self.last_planned_k.get() == Some((E::DTYPE, dims.k));
        let width = self.plan.threads.max(1);
        let mut ranked: Vec<(GemmConfig, f64)> = sel
            .ranked
            .into_iter()
            .map(|(cfg, analytic)| {
                // Warm-state sequence discount: when the k-panel is
                // resident from the previous pipeline iteration the
                // A-pack pass mostly hits cache, so the prior drops that
                // term (floored at half the cold estimate — packing is
                // never entirely free).
                let prior = if warm {
                    let pack =
                        AnalyticScorer.pack_a_cost_elem(&self.arch, dims, cfg.mk, cfg.ccp, esize);
                    (analytic - pack).max(0.5 * analytic)
                } else {
                    analytic
                };
                (cfg, profile.blend(dims, E::DTYPE, cfg, width, prior))
            })
            .collect();
        ranked.sort_by(|a, b| a.1.total_cmp(&b.1));
        let tick = self.explore_tick.get() + 1;
        self.explore_tick.set(tick);
        if self.explore_allowed.get() && ranked.len() > 1 && tick % Self::EXPLORE_PERIOD == 0 {
            profile.note_exploration();
            return (ranked[1].0, true);
        }
        (ranked[0].0, false)
    }

    /// [`Self::plan_config`] per element type. The memo key carries the
    /// dtype, so an f32 and an f64 request of equal shape each get (and
    /// cache) their own width-aware selection.
    pub fn plan_config_t<E: GemmElem>(&self, dims: GemmDims) -> GemmConfig {
        let verified = self.verify.enabled();
        // The profile's generation is part of the memo key: a bump (every
        // ~32 observations, and on clear) turns cached selections into
        // fresh misses, which is where new measurements — and exploration
        // — get to change a decision. Without a profile the generation is
        // the constant 0 and the key behaves exactly as before.
        let calibrated = self.profile.is_some();
        let gen = self.profile.as_ref().map_or(0, |p| p.generation());
        let key = (mode_key(&self.mode), E::DTYPE, dims, verified, gen);
        if let Some(cfg) = self.config_cache.borrow().get(&key) {
            let mut s = self.cache_stats.get();
            s.hits += 1;
            self.cache_stats.set(s);
            if calibrated {
                self.last_planned_k.set(Some((E::DTYPE, dims.k)));
            }
            return *cfg;
        }
        let (mut cfg, explored) = self.compute_config_calibrated::<E>(dims);
        if calibrated {
            self.last_planned_k.set(Some((E::DTYPE, dims.k)));
        }
        if verified {
            // Verified dispatches carry checksum state alongside the
            // packed panels (reference sums, pre/post C sums, and in
            // correct mode a saved copy of the active C region). Shave
            // one granule off mc and nc so the resident set still fits
            // the cache level the model sized the block for. kc is
            // untouched: only the k-blocking determines each element's
            // accumulation grouping, so the verified schedule stays
            // bitwise identical to the unverified one.
            cfg.ccp.mc = cfg.ccp.mc.saturating_sub(cfg.mk.mr).max(cfg.mk.mr);
            cfg.ccp.nc = cfg.ccp.nc.saturating_sub(cfg.mk.nr).max(cfg.mk.nr);
        }
        if !explored {
            // An exploration trial is a one-shot: memoizing it would pin
            // the deliberately sub-optimal candidate until the next
            // generation bump.
            let mut cache = self.config_cache.borrow_mut();
            if cache.len() >= Self::CONFIG_CACHE_CAP {
                cache.clear();
            }
            cache.insert(key, cfg);
        }
        let mut s = self.cache_stats.get();
        s.misses += 1;
        self.cache_stats.set(s);
        cfg
    }

    /// Memo-cache accounting (hits/misses of [`Self::plan_config`]).
    pub fn config_cache_stats(&self) -> ConfigCacheStats {
        self.cache_stats.get()
    }

    /// Number of selections currently memoized (bounded by the cap).
    pub fn config_cache_len(&self) -> usize {
        self.config_cache.borrow().len()
    }

    /// Drop all memoized selections — GEMM configs, team sizes *and*
    /// batch cost estimates — and reset the accountings. With
    /// calibration on, the measurement store and exploration state are
    /// cleared too (and the store's generation bumps): measurements
    /// taken under an old plan or arch must never influence selections
    /// after the change.
    pub fn clear_config_cache(&mut self) {
        self.config_cache.borrow_mut().clear();
        self.cache_stats.set(ConfigCacheStats::default());
        self.team_sizer.clear();
        self.batch_planner.clear();
        self.explore_tick.set(0);
        self.last_planned_k.set(None);
        if let Some(p) = &self.profile {
            p.clear();
        }
    }

    /// Memoized configuration **and** its runnable kernel implementation
    /// for `dims` — what the deep-lookahead chains need to replay a
    /// future iteration's trailing update bitwise-identically from
    /// inside a pool job.
    pub fn plan_kernel(&self, dims: GemmDims) -> (GemmConfig, MicroKernelImpl) {
        self.plan_kernel_t::<f64>(dims)
    }

    /// [`Self::plan_kernel`] per element type.
    pub fn plan_kernel_t<E: GemmElem>(&self, dims: GemmDims) -> (GemmConfig, MicroKernelImpl<E>) {
        let cfg = self.plan_config_t::<E>(dims);
        (cfg, self.implementation_for_t::<E>(cfg.mk))
    }

    /// Model-selected tile size for the blocked/DAG factorizations of an
    /// order-`s` problem at element type `E`: the analytic scorer's
    /// cache-sized k-block (`kc`) for the square `s` shape. Every
    /// trailing tile GEMM of a blocked factorization has k-dimension
    /// equal to the tile width, so tiles of width `kc` stream through
    /// the cache exactly as the model planned — and the selection is
    /// dtype-aware (f32 configs are wider). The factorization drivers
    /// use this when called with the `block == 0` sentinel.
    pub fn dag_tile_size_t<E: GemmElem>(&self, s: usize) -> usize {
        if s == 0 {
            return 1;
        }
        let cfg = self.plan_config_t::<E>(GemmDims::new(s, s, s));
        cfg.ccp.kc.clamp(1, s)
    }

    /// The panel sub-team width `t_p` for one fused iteration
    /// (`iteration` counts factorization steps from 0). `la` is the
    /// policy the caller resolved **once** per factorization with
    /// [`Self::lookahead`] — passing it in keeps this per-iteration call
    /// free of environment lookups and allocation (the acceptance
    /// criterion for the hot path). Resolution order: a non-zero
    /// `panel_workers` pinned on the policy, then a comma-separated
    /// `DLA_PANEL_WORKERS` schedule (entry per iteration, last repeats),
    /// then the memoized team-size model balancing the panel critical
    /// path against the trailing sweep under the configuration selected
    /// for `update`.
    pub fn panel_team_size(
        &self,
        la: Lookahead,
        iteration: usize,
        panel: PanelShape,
        update: GemmDims,
    ) -> usize {
        self.panel_team_size_t::<f64>(la, iteration, panel, update)
    }

    /// [`Self::panel_team_size`] per element type: the team-size model
    /// keys its memo by dtype and scores with the width-scaled peak (an
    /// f32 panel runs at twice the scalar rate, so the balance point
    /// moves).
    pub fn panel_team_size_t<E: GemmElem>(
        &self,
        la: Lookahead,
        iteration: usize,
        panel: PanelShape,
        update: GemmDims,
    ) -> usize {
        let threads = self.plan.threads;
        if threads <= 2 {
            return 1;
        }
        if la.panel_workers != AUTO_PANEL_WORKERS {
            return la.panel_workers.min(threads - 1);
        }
        if let Some(schedule) = &self.panel_schedule {
            let idx = iteration.min(schedule.len() - 1);
            return schedule[idx].min(threads - 1);
        }
        let cfg = self.plan_config_t::<E>(update);
        let esize = E::DTYPE.size_bytes();
        match &self.profile {
            Some(p) => {
                // Calibrated: the min-max balance judges the trailing
                // sweep by the blended (measured-refined) single-core
                // estimate instead of the raw analytic score, keyed by
                // the profile generation so a hotter store re-balances.
                let analytic = AnalyticScorer.score_elem(&self.arch, update, cfg.mk, cfg.ccp, esize);
                let blended = p.blend_serial(update, E::DTYPE, cfg, analytic);
                self.team_sizer.select_elem_with(
                    &self.arch,
                    cfg,
                    panel,
                    update,
                    threads,
                    esize,
                    p.generation(),
                    Some(blended),
                )
            }
            None => self.team_sizer.select_elem(&self.arch, cfg, panel, update, threads, esize),
        }
    }

    /// Hit/miss accounting of the team-size memo cache (the malleable
    /// `t_p` selector), alongside [`Self::config_cache_stats`].
    pub fn team_size_cache_stats(&self) -> TeamSizeStats {
        self.team_sizer.stats()
    }

    /// Dispatch one configured GEMM to the pool-parallel or sequential
    /// blocked driver. With calibration on, the dispatch is bracketed by
    /// one `Instant` pair (the epoch boundaries the pool's `PoolStats`
    /// already account — no extra syscalls inside the epoch) and the
    /// measured GFLOPS lands in the profile under the dispatched config
    /// and team width.
    #[allow(clippy::too_many_arguments)]
    fn dispatch<E: GemmElem>(
        &mut self,
        cfg: &GemmConfig,
        kernel: &MicroKernelImpl<E>,
        alpha: E,
        a: MatView<'_, E>,
        b: MatView<'_, E>,
        beta: E,
        c: &mut MatViewMut<'_, E>,
    ) {
        match self.profile.clone() {
            Some(profile) => {
                let dims = GemmDims::new(a.rows, b.cols, a.cols);
                let width = self.plan.threads.max(1);
                let t0 = Instant::now();
                self.dispatch_inner(cfg, kernel, alpha, a, b, beta, c);
                profile.record(dims, E::DTYPE, *cfg, width, t0.elapsed().as_secs_f64());
            }
            None => self.dispatch_inner(cfg, kernel, alpha, a, b, beta, c),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn dispatch_inner<E: GemmElem>(
        &mut self,
        cfg: &GemmConfig,
        kernel: &MicroKernelImpl<E>,
        alpha: E,
        a: MatView<'_, E>,
        b: MatView<'_, E>,
        beta: E,
        c: &mut MatViewMut<'_, E>,
    ) {
        if self.verify.enabled() {
            self.abft.begin_epoch();
            let faults = self.pool.as_ref().and_then(|p| p.fault_state());
            let epoch = faults.as_ref().map_or(0, |f| f.begin_verified_epoch());
            let ctx = AbftCtx {
                policy: self.verify,
                stats: self.abft.as_ref(),
                faults: faults.as_deref(),
                epoch,
            };
            match &self.pool {
                Some(pool) if self.plan.threads > 1 => {
                    gemm_parallel_abft(
                        cfg,
                        kernel,
                        alpha,
                        a,
                        b,
                        beta,
                        c,
                        self.plan.target,
                        pool,
                        Some(&ctx),
                    );
                }
                _ => {
                    gemm_blocked_abft(cfg, kernel, alpha, a, b, beta, c, &mut self.workspace, &ctx)
                }
            }
            return;
        }
        match &self.pool {
            Some(pool) if self.plan.threads > 1 => {
                gemm_parallel_abft(
                    cfg, kernel, alpha, a, b, beta, c, self.plan.target, pool, None,
                );
            }
            _ => gemm_blocked(cfg, kernel, alpha, a, b, beta, c, &mut self.workspace),
        }
    }

    /// `C = alpha * A * B + beta * C` (FP64).
    pub fn gemm(
        &mut self,
        alpha: f64,
        a: MatView<'_>,
        b: MatView<'_>,
        beta: f64,
        c: &mut MatViewMut<'_>,
    ) {
        self.gemm_t(alpha, a, b, beta, c);
    }

    /// `C = alpha * A * B + beta * C` in f32: same pooled G3/G4 drivers,
    /// same memoized selection — with the width-aware (larger) CCPs and
    /// the double-lane kernel family.
    pub fn gemm_f32(
        &mut self,
        alpha: f32,
        a: MatView<'_, f32>,
        b: MatView<'_, f32>,
        beta: f32,
        c: &mut MatViewMut<'_, f32>,
    ) {
        self.gemm_t(alpha, a, b, beta, c);
    }

    /// The dtype-generic GEMM entry point behind [`Self::gemm`] /
    /// [`Self::gemm_f32`].
    pub fn gemm_t<E: GemmElem>(
        &mut self,
        alpha: E,
        a: MatView<'_, E>,
        b: MatView<'_, E>,
        beta: E,
        c: &mut MatViewMut<'_, E>,
    ) {
        let dims = GemmDims::new(a.rows, b.cols, a.cols);
        let cfg = self.plan_config_t::<E>(dims);
        let kernel = self.implementation_for_t::<E>(cfg.mk);
        self.last_config = Some(cfg);
        self.dispatch(&cfg, &kernel, alpha, a, b, beta, c);
    }

    /// Execute a batch of **independent** GEMMs (`C = alpha*A*B + beta*C`
    /// each) as fused pool epochs: every member keeps its own memoized
    /// per-shape configuration (so a batched request selects exactly the
    /// config a solo dispatch would), the team is partitioned across the
    /// members by the [`crate::model::batchplan`] cost model, and batches
    /// wider than the team are chunked — at most `threads` members per
    /// epoch, every member owning at least one rank. Returns the config
    /// chosen for each item, in order.
    ///
    /// Bitwise identical per member to serving the same requests one at
    /// a time through [`Self::gemm`] (asserted by `tests/batching.rs`):
    /// the per-group G4 schedule is the solo schedule at a smaller team
    /// width, and the G4 schedule's results are width-independent.
    /// Without a multi-thread pool the members run inline, in order.
    pub fn gemm_batch(&mut self, items: &mut [GemmBatchItem<'_>]) -> Vec<GemmConfig> {
        self.gemm_batch_t::<f64>(items)
    }

    /// The dtype-generic fused batch behind [`Self::gemm_batch`]: f32
    /// batches run the same one-group-per-member pool epochs with their
    /// own width-aware per-member configs.
    pub fn gemm_batch_t<E: GemmElem>(&mut self, items: &mut [GemmBatchItem<'_, E>]) -> Vec<GemmConfig> {
        let configs: Vec<GemmConfig> = items
            .iter()
            .map(|it| self.plan_config_t::<E>(GemmDims::new(it.a.rows, it.b.cols, it.a.cols)))
            .collect();
        if let Some(cfg) = configs.last() {
            self.last_config = Some(*cfg);
        }
        // Verified mode serializes the members through the verified
        // dispatch path: the fused batch driver shares pool barriers
        // across member groups and stays unverified by design (the
        // coordinator routes verified requests around the batcher too).
        let pooled = self.plan.threads > 1 && self.pool.is_some() && !self.verify.enabled();
        if !pooled {
            // Serialized fallback: identical to handling each request
            // alone on this engine.
            for (it, cfg) in items.iter_mut().zip(&configs) {
                let kernel = self.implementation_for_t::<E>(cfg.mk);
                self.dispatch(cfg, &kernel, it.alpha, it.a, it.b, it.beta, &mut it.c);
            }
            return configs;
        }
        let pool = Arc::clone(self.pool.as_ref().expect("pooled engine"));
        let threads = pool.threads();
        let mut idx = 0;
        while idx < items.len() {
            let len = (items.len() - idx).min(threads);
            let chunk_cfgs = &configs[idx..idx + len];
            let planned: Vec<(GemmConfig, GemmDims)> = items[idx..idx + len]
                .iter()
                .zip(chunk_cfgs)
                .map(|(it, cfg)| (*cfg, GemmDims::new(it.a.rows, it.b.cols, it.a.cols)))
                .collect();
            let shares = self.batch_planner.partition_team_elem(
                &self.arch,
                &planned,
                threads,
                E::DTYPE.size_bytes(),
            );
            let mut members: Vec<BatchGemm<'_, E>> = items[idx..idx + len]
                .iter_mut()
                .zip(chunk_cfgs)
                .map(|(it, cfg)| BatchGemm {
                    cfg: *cfg,
                    kernel: self.implementation_for_t::<E>(cfg.mk),
                    alpha: it.alpha,
                    a: it.a,
                    b: it.b,
                    beta: it.beta,
                    c: it.c.sub_mut(0, 0, it.c.rows, it.c.cols),
                })
                .collect();
            gemm_batch_parallel(&mut members, &shares, &pool);
            idx += len;
        }
        configs
    }

    /// Lookahead-fused trailing update `C += alpha * A * B`: the first
    /// `split_col` columns of C are updated first, then `panel_workers`
    /// pool ranks run `panel_task` on them (factor the next panel) while
    /// the rest of the team finishes the remaining columns; one team
    /// barrier rejoins. The depth-1 special case of
    /// [`Self::gemm_fused_trailing_ranges`].
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_fused_trailing(
        &mut self,
        alpha: f64,
        a: MatView<'_>,
        b: MatView<'_>,
        c: &mut MatViewMut<'_>,
        split_col: usize,
        panel_workers: usize,
        panel_task: &(dyn Fn(&SubTeam<'_>) + Sync),
    ) {
        let n = b.cols;
        assert!(split_col <= n, "split_col out of range");
        self.gemm_fused_trailing_ranges_t::<f64>(
            alpha,
            a,
            b,
            c,
            &[(0, split_col)],
            (split_col, n),
            panel_workers,
            false,
            panel_task,
        );
    }

    /// The general fused trailing update of the deep-lookahead pipeline
    /// (see [`crate::gemm::parallel::gemm_fused_trailing_ranges`]): the
    /// full team updates the pending-panel `head` ranges first, then the
    /// panel sub-team runs `panel_task` while the update sub-team sweeps
    /// `tail`; columns outside `head ∪ tail` are untouched. The
    /// configuration is planned **once on the full trailing dimensions**,
    /// so the column decomposition is bitwise identical to a plain
    /// [`Self::gemm`] of the whole update (the k-blocking is what
    /// determines each element's accumulation order). Without a
    /// multi-thread pool the same schedule runs inline.
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_fused_trailing_ranges(
        &mut self,
        alpha: f64,
        a: MatView<'_>,
        b: MatView<'_>,
        c: &mut MatViewMut<'_>,
        head: &[(usize, usize)],
        tail: (usize, usize),
        panel_workers: usize,
        panel_queue_empty: bool,
        panel_task: &(dyn Fn(&SubTeam<'_>) + Sync),
    ) {
        self.gemm_fused_trailing_ranges_t::<f64>(
            alpha,
            a,
            b,
            c,
            head,
            tail,
            panel_workers,
            panel_queue_empty,
            panel_task,
        );
    }

    /// The dtype-generic fused trailing update behind
    /// [`Self::gemm_fused_trailing_ranges`] — what the generic (f64/f32)
    /// lookahead LU pipeline drives.
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_fused_trailing_ranges_t<E: GemmElem>(
        &mut self,
        alpha: E,
        a: MatView<'_, E>,
        b: MatView<'_, E>,
        c: &mut MatViewMut<'_, E>,
        head: &[(usize, usize)],
        tail: (usize, usize),
        panel_workers: usize,
        panel_queue_empty: bool,
        panel_task: &(dyn Fn(&SubTeam<'_>) + Sync),
    ) {
        let dims = GemmDims::new(a.rows, b.cols, a.cols);
        let cfg = self.plan_config_t::<E>(dims);
        let kernel = self.implementation_for_t::<E>(cfg.mk);
        self.last_config = Some(cfg);
        let verified = self.verify.enabled();
        let faults = if verified {
            self.abft.begin_epoch();
            self.pool.as_ref().and_then(|p| p.fault_state())
        } else {
            None
        };
        let epoch = faults.as_ref().map_or(0, |f| f.begin_verified_epoch());
        let ctx = AbftCtx {
            policy: self.verify,
            stats: self.abft.as_ref(),
            faults: faults.as_deref(),
            epoch,
        };
        let abft = verified.then_some(&ctx);
        // Calibration timing for the pipeline's fused epochs: the
        // measurement covers the whole epoch (trailing sweep + the
        // overlapped panel work), which is exactly the cost the
        // selector should optimize — the epoch ends when both halves
        // do.
        let timer = self.profile.as_ref().map(|p| (Arc::clone(p), Instant::now()));
        match &self.pool {
            Some(pool) => {
                gemm_fused_trailing_ranges_abft(
                    &cfg,
                    &kernel,
                    alpha,
                    a,
                    b,
                    c,
                    head,
                    tail,
                    panel_workers,
                    panel_queue_empty,
                    panel_task,
                    pool,
                    abft,
                );
            }
            None => {
                gemm_fused_trailing_ranges_seq(
                    &cfg, &kernel, alpha, a, b, c, head, tail, panel_task, &mut self.workspace,
                    abft,
                );
            }
        }
        if let Some((profile, t0)) = timer {
            let width = self.plan.threads.max(1);
            profile.record(dims, E::DTYPE, cfg, width, t0.elapsed().as_secs_f64());
        }
    }

    /// Run with an explicit configuration, bypassing the policy (used by
    /// the experiment harness).
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_with_config(
        &mut self,
        cfg: &GemmConfig,
        alpha: f64,
        a: MatView<'_>,
        b: MatView<'_>,
        beta: f64,
        c: &mut MatViewMut<'_>,
    ) {
        let kernel = self.implementation_for(cfg.mk);
        self.last_config = Some(*cfg);
        self.dispatch(cfg, &kernel, alpha, a, b, beta, c);
    }

    /// Run with an explicit named kernel (including prefetch variants).
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_with_kernel_name(
        &mut self,
        name: &str,
        ccp: crate::model::Ccp,
        alpha: f64,
        a: MatView<'_>,
        b: MatView<'_>,
        beta: f64,
        c: &mut MatViewMut<'_>,
    ) {
        let kernel = self
            .kernels
            .iter()
            .find(|k| k.name == name)
            .copied()
            .unwrap_or_else(|| panic!("kernel {name} not registered"));
        let dims = GemmDims::new(a.rows, b.cols, a.cols);
        let cfg = GemmConfig { mk: kernel.spec, ccp: ccp.clamp_to(dims) };
        self.last_config = Some(cfg);
        gemm_blocked(&cfg, &kernel, alpha, a, b, beta, c, &mut self.workspace);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{carmel, epyc7282, host_xeon};
    use crate::gemm::gemm_reference;
    use crate::util::{MatrixF64, Pcg64};

    fn check_engine(mut eng: GemmEngine, m: usize, n: usize, k: usize) -> GemmConfig {
        let mut rng = Pcg64::seed(77);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let mut c = MatrixF64::random(m, n, &mut rng);
        let mut expect = c.clone();
        gemm_reference(1.5, a.view(), b.view(), 0.5, &mut expect.view_mut());
        eng.gemm(1.5, a.view(), b.view(), 0.5, &mut c.view_mut());
        assert!(c.max_abs_diff(&expect) < 1e-12 * k as f64, "engine mode {:?}", eng.mode);
        eng.last_config.unwrap()
    }

    #[test]
    fn all_modes_correct() {
        for mode in [
            ConfigMode::BlisStatic,
            ConfigMode::OriginalModel,
            ConfigMode::Refined,
            ConfigMode::RefinedWithKernel(MicroKernel::new(12, 4)),
        ] {
            check_engine(GemmEngine::new(carmel(), mode), 70, 50, 30);
        }
    }

    #[test]
    fn refined_mode_adapts_ccp_to_k() {
        let eng = GemmEngine::new(epyc7282(), ConfigMode::Refined);
        let skinny = eng.plan_config(GemmDims::new(2000, 2000, 64));
        let fat = eng.plan_config(GemmDims::new(2000, 2000, 2000));
        assert!(skinny.ccp.mc > fat.ccp.mc, "refined mc must grow as k shrinks");
        assert_eq!(skinny.ccp.kc, 64);
    }

    #[test]
    fn blis_static_mode_pins_ccp() {
        let eng = GemmEngine::new(carmel(), ConfigMode::BlisStatic);
        let cfg = eng.plan_config(GemmDims::new(2000, 2000, 128));
        assert_eq!(cfg.ccp, crate::model::Ccp::new(120, 2000, 128));
        assert_eq!(cfg.mk, MicroKernel::new(6, 8));
    }

    #[test]
    fn parallel_engine_correct_and_pool_persistent() {
        let eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads: 3, target: crate::gemm::ParallelLoop::G4 });
        let pool = Arc::clone(eng.pool().expect("parallel plan provisions a pool"));
        check_engine(eng, 90, 70, 40);
        assert_eq!(pool.threads(), 3);
        assert_eq!(pool.spawned_workers(), 2, "exactly threads-1 workers, spawned once");
    }

    #[test]
    fn with_plan_keeps_existing_pool_for_same_width() {
        let eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads: 3, target: crate::gemm::ParallelLoop::G4 });
        let first = Arc::clone(eng.pool().unwrap());
        let eng = eng.with_plan(ThreadPlan { threads: 3, target: crate::gemm::ParallelLoop::G3 });
        assert!(Arc::ptr_eq(&first, eng.pool().unwrap()), "same width must reuse the pool");
    }

    #[test]
    fn config_cache_hits_and_misses_are_accounted() {
        let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
        let dims = GemmDims::new(120, 120, 24);
        let first = eng.plan_config(dims);
        assert_eq!(eng.config_cache_stats(), ConfigCacheStats { hits: 0, misses: 1 });
        for _ in 0..4 {
            assert_eq!(eng.plan_config(dims), first, "cached selection must be stable");
        }
        assert_eq!(eng.config_cache_stats(), ConfigCacheStats { hits: 4, misses: 1 });
        // A different shape is a fresh miss...
        eng.plan_config(GemmDims::new(60, 60, 24));
        assert_eq!(eng.config_cache_stats(), ConfigCacheStats { hits: 4, misses: 2 });
        // ...and so is the same shape under a different mode (stale-mode
        // entries must never be served).
        eng.mode = ConfigMode::BlisStatic;
        let blis = eng.plan_config(dims);
        assert_eq!(eng.config_cache_stats(), ConfigCacheStats { hits: 4, misses: 3 });
        assert_ne!(blis, first);
        eng.clear_config_cache();
        assert_eq!(eng.config_cache_stats(), ConfigCacheStats::default());
    }

    #[test]
    fn config_cache_is_bounded() {
        // A server engine fed ever-changing shapes must not grow without
        // bound: the map flushes at the cap, stats keep counting.
        let eng =
            GemmEngine::new(host_xeon(), ConfigMode::RefinedWithKernel(MicroKernel::new(8, 6)));
        let n = GemmEngine::CONFIG_CACHE_CAP + 100;
        for i in 0..n {
            eng.plan_config(GemmDims::new(8 + i, 8, 8));
        }
        assert!(eng.config_cache_len() <= GemmEngine::CONFIG_CACHE_CAP);
        assert_eq!(eng.config_cache_stats().misses, n as u64);
    }

    #[test]
    fn lookahead_heuristic_scales_with_team_width() {
        assert!(!Lookahead::heuristic(1).enabled());
        // Multi-thread teams default to depth-1 with model-driven t_p.
        for t in [2, 4, 16, 64] {
            assert_eq!(
                Lookahead::heuristic(t),
                Lookahead { depth: 1, panel_workers: AUTO_PANEL_WORKERS }
            );
        }
        assert!(!Lookahead::disabled().enabled());
    }

    #[test]
    fn lookahead_validation_rejects_malformed_policies() {
        // depth 0 with a panel team is malformed at any width.
        let bad = Lookahead { depth: 0, panel_workers: 2 };
        assert!(bad.validate(1).is_err());
        assert!(bad.validate(8).is_err());
        // panel_workers must leave the update team non-empty.
        let greedy = Lookahead { depth: 1, panel_workers: 4 };
        assert!(greedy.validate(4).is_err());
        assert!(greedy.validate(3).is_err());
        assert!(greedy.validate(5).is_ok());
        // A single-thread plan runs the inline path; any t_p is fine.
        assert!(greedy.validate(1).is_ok());
        // Auto sizing and disabled() are always valid.
        assert!(Lookahead::heuristic(4).validate(4).is_ok());
        assert!(Lookahead::disabled().validate(4).is_ok());
    }

    #[test]
    #[should_panic(expected = "invalid lookahead policy")]
    fn engine_rejects_panel_team_swallowing_the_pool() {
        let _ = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads: 2, target: crate::gemm::ParallelLoop::G4 })
            .with_lookahead(Lookahead { depth: 1, panel_workers: 2 });
    }

    #[test]
    fn engine_lookahead_defaults_and_pinning() {
        // The default-resolution asserts only hold when the CI matrix is
        // not overriding DLA_LOOKAHEAD (the depth-2 leg flips un-pinned
        // engines on purpose); a pinned policy must win regardless.
        let env_clear =
            std::env::var("DLA_LOOKAHEAD").map(|v| v.trim().is_empty()).unwrap_or(true);
        if env_clear {
            let seq = GemmEngine::new(host_xeon(), ConfigMode::Refined);
            assert!(!seq.lookahead().enabled(), "sequential engine: lookahead off by default");
        }
        let par = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads: 4, target: crate::gemm::ParallelLoop::G4 });
        if env_clear {
            assert_eq!(par.lookahead().depth, 1);
        }
        let pinned = par.with_lookahead(Lookahead { depth: 2, panel_workers: 2 });
        assert_eq!(pinned.lookahead(), Lookahead { depth: 2, panel_workers: 2 });
    }

    #[test]
    fn panel_team_size_resolution_order() {
        // Pinned t_p wins over the model; narrow teams always get 1.
        let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads: 4, target: crate::gemm::ParallelLoop::G4 });
        eng.set_lookahead(Lookahead { depth: 2, panel_workers: 3 });
        let update = GemmDims::new(256, 256, 32);
        let panel = crate::model::PanelShape::new(256, 32);
        assert_eq!(eng.panel_team_size(eng.lookahead(), 0, panel, update), 3);
        // Model-driven: in-bounds and memoized.
        eng.set_lookahead(Lookahead { depth: 2, panel_workers: AUTO_PANEL_WORKERS });
        let auto = eng.lookahead();
        let t0 = eng.panel_team_size(auto, 0, panel, update);
        assert!((1..4).contains(&t0));
        let before = eng.team_size_cache_stats();
        assert_eq!(eng.panel_team_size(auto, 5, panel, update), t0);
        let after = eng.team_size_cache_stats();
        assert_eq!(after.hits, before.hits + 1, "repeat lookup must be a cache hit");
        // clear_config_cache drops the team-size memo too.
        eng.clear_config_cache();
        assert_eq!(eng.team_size_cache_stats(), crate::model::TeamSizeStats::default());
        // Two-thread plans never split below a 1-rank update team.
        let eng2 = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads: 2, target: crate::gemm::ParallelLoop::G4 });
        assert_eq!(eng2.panel_team_size(eng2.lookahead(), 0, panel, update), 1);
    }

    #[test]
    #[should_panic(expected = "invalid lookahead policy for the new plan")]
    fn with_plan_revalidates_a_pinned_policy() {
        // Pin-then-plan must not dodge the panel_workers < threads check.
        let _ = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_lookahead(Lookahead { depth: 1, panel_workers: 4 })
            .with_plan(ThreadPlan { threads: 4, target: crate::gemm::ParallelLoop::G4 });
    }

    #[test]
    fn engine_fused_trailing_matches_plain_gemm() {
        let mut rng = Pcg64::seed(99);
        let (m, n, k, split) = (50, 41, 9, 11);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let c0 = MatrixF64::random(m, n, &mut rng);
        // Reference: one whole-update gemm on an identically-configured
        // engine (same mode => same planned config).
        let mut c_ref = c0.clone();
        let mut ref_eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads: 3, target: crate::gemm::ParallelLoop::G4 });
        ref_eng.gemm(-1.0, a.view(), b.view(), 1.0, &mut c_ref.view_mut());
        for threads in [1, 3] {
            let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
                .with_plan(ThreadPlan { threads, target: crate::gemm::ParallelLoop::G4 });
            let mut c = c0.clone();
            eng.gemm_fused_trailing(-1.0, a.view(), b.view(), &mut c.view_mut(), split, 1, &|_| {});
            assert_eq!(
                c.max_abs_diff(&c_ref),
                0.0,
                "fused trailing (x{threads}) must be bitwise identical to plain gemm"
            );
        }
        // And a pool-less engine takes the inline path with the same result.
        let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
        let mut c = c0.clone();
        eng.gemm_fused_trailing(-1.0, a.view(), b.view(), &mut c.view_mut(), split, 1, &|_| {});
        assert_eq!(c.max_abs_diff(&c_ref), 0.0);
    }

    #[test]
    fn engine_batch_bitwise_matches_serial_engine_and_memoizes() {
        // 5 members on a 4-thread pool exercises chunking (4 + 1); the
        // repeated shape exercises the config memo across batch members.
        let shapes = [(40usize, 24usize, 16usize), (24, 40, 8), (33, 17, 9), (40, 24, 16), (8, 8, 8)];
        let coeffs = [(1.0, 0.0), (-1.0, 1.0), (0.5, -2.0), (2.0, 1.0), (1.0, 1.0)];
        let mut rng = Pcg64::seed(4242);
        let inputs: Vec<(MatrixF64, MatrixF64, MatrixF64)> = shapes
            .iter()
            .map(|&(m, n, k)| {
                (
                    MatrixF64::random(m, k, &mut rng),
                    MatrixF64::random(k, n, &mut rng),
                    MatrixF64::random(m, n, &mut rng),
                )
            })
            .collect();
        // Serial reference: one request at a time through engine.gemm.
        let mut refs = Vec::new();
        {
            let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
                .with_plan(ThreadPlan { threads: 4, target: crate::gemm::ParallelLoop::G4 });
            for ((a, b, c0), (alpha, beta)) in inputs.iter().zip(coeffs) {
                let mut c = c0.clone();
                eng.gemm(alpha, a.view(), b.view(), beta, &mut c.view_mut());
                refs.push(c);
            }
        }
        for threads in [1usize, 4] {
            let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
                .with_plan(ThreadPlan { threads, target: crate::gemm::ParallelLoop::G4 });
            let mut cs: Vec<MatrixF64> = inputs.iter().map(|(_, _, c0)| c0.clone()).collect();
            let mut items: Vec<GemmBatchItem<'_>> = inputs
                .iter()
                .zip(cs.iter_mut())
                .zip(coeffs)
                .map(|(((a, b, _), c), (alpha, beta))| GemmBatchItem {
                    alpha,
                    a: a.view(),
                    b: b.view(),
                    beta,
                    c: c.view_mut(),
                })
                .collect();
            let configs = eng.gemm_batch(&mut items);
            drop(items);
            assert_eq!(configs.len(), 5);
            // Repeated shape (items 0 and 3) must resolve to one memoized
            // selection: 4 distinct shapes -> 4 misses, 1 hit.
            let stats = eng.config_cache_stats();
            assert_eq!(stats.misses, 4, "x{threads}: {stats:?}");
            assert_eq!(stats.hits, 1, "x{threads}: {stats:?}");
            assert_eq!(configs[0], configs[3]);
            for (i, (c, expect)) in cs.iter().zip(&refs).enumerate() {
                assert_eq!(
                    c.max_abs_diff(expect),
                    0.0,
                    "batched member {i} (x{threads}) must be bitwise identical to serial"
                );
            }
        }
    }

    #[test]
    fn engine_family_nonempty_and_deduped() {
        let eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
        let fam = eng.family();
        assert!(!fam.is_empty());
        let mut f2 = fam.clone();
        f2.dedup();
        assert_eq!(fam, f2);
    }
}
