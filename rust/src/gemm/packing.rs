//! Packing routines (paper Figure 3, bottom-right).
//!
//! `pack_a` copies an `mc x kc` block of A into the contiguous buffer `Ac`
//! laid out as a sequence of `mr x kc` micro-panels: panel i holds rows
//! `[i*mr, (i+1)*mr)` and stores, for each p in `0..kc`, the `mr` elements
//! of column p consecutively. The micro-kernel then loads one column of
//! `Ar` with consecutive (SIMD-friendly) reads.
//!
//! `pack_b` copies a `kc x nc` block of B into `Bc` as `kc x nr`
//! micro-panels: panel j holds columns `[j*nr, (j+1)*nr)` and stores, for
//! each p, the `nr` elements of row p consecutively.
//!
//! Fringe micro-panels are zero-padded to full `mr`/`nr` so the
//! micro-kernel never needs edge cases on the packed side; the extra
//! zeros contribute nothing to the rank-1 updates.

use crate::util::elem::Elem;
use crate::util::matrix::MatView;

/// Number of elements `pack_a` writes for an `mc x kc` block.
pub fn packed_a_len(mc: usize, kc: usize, mr: usize) -> usize {
    mc.div_ceil(mr) * mr * kc
}

/// Number of elements `pack_b` writes for a `kc x nc` block.
pub fn packed_b_len(kc: usize, nc: usize, nr: usize) -> usize {
    nc.div_ceil(nr) * nr * kc
}

/// Pack `a` (an `mc x kc` view) into `buf` as `mr`-row micro-panels,
/// scaling every element by `alpha` (folding the GEMM alpha into the
/// packed operand keeps the micro-kernels pure accumulate).
pub fn pack_a<E: Elem>(a: MatView<'_, E>, buf: &mut [E], mr: usize, alpha: E) {
    let (mc, kc) = (a.rows, a.cols);
    let n_panels = mc.div_ceil(mr);
    assert!(buf.len() >= n_panels * mr * kc, "pack_a buffer too small");
    let mut off = 0;
    for ip in 0..n_panels {
        let i0 = ip * mr;
        let rows = mr.min(mc - i0);
        if rows == mr {
            // Full panel: tight copy loop (the hot path). alpha == 1.0 is
            // the common case (LU folds its -1 into alpha only once per
            // call) and turns into a straight memcpy per column.
            if alpha == E::ONE {
                for p in 0..kc {
                    let col = &a.data[p * a.ld + i0..p * a.ld + i0 + mr];
                    buf[off..off + mr].copy_from_slice(col);
                    off += mr;
                }
            } else {
                for p in 0..kc {
                    let col = &a.data[p * a.ld + i0..p * a.ld + i0 + mr];
                    let dst = &mut buf[off..off + mr];
                    for (d, &s) in dst.iter_mut().zip(col) {
                        *d = alpha * s;
                    }
                    off += mr;
                }
            }
        } else {
            // Fringe panel: zero-pad the missing rows.
            for p in 0..kc {
                for r in 0..rows {
                    buf[off + r] = alpha * a.at(i0 + r, p);
                }
                for r in rows..mr {
                    buf[off + r] = E::ZERO;
                }
                off += mr;
            }
        }
    }
}

/// Pack `b` (a `kc x nc` view) into `buf` as `nr`-column micro-panels.
pub fn pack_b<E: Elem>(b: MatView<'_, E>, buf: &mut [E], nr: usize) {
    let (kc, nc) = (b.rows, b.cols);
    let n_panels = nc.div_ceil(nr);
    assert!(buf.len() >= n_panels * nr * kc, "pack_b buffer too small");
    let mut off = 0;
    for jp in 0..n_panels {
        let j0 = jp * nr;
        let cols = nr.min(nc - j0);
        for p in 0..kc {
            for c in 0..cols {
                buf[off + c] = b.at(p, j0 + c);
            }
            for c in cols..nr {
                buf[off + c] = E::ZERO;
            }
            off += nr;
        }
    }
}

/// Elements a [`pack_a_checked`] buffer needs for an `mc x kc` block:
/// the packed micro-panels plus a `2*kc` checksum tail (column sums and
/// absolute column sums, one each per p).
pub fn packed_a_len_checked(mc: usize, kc: usize, mr: usize) -> usize {
    packed_a_len(mc, kc, mr) + 2 * kc
}

/// Elements a [`pack_b_checked`] buffer needs for a `kc x nc` block:
/// the packed micro-panels plus a `2*kc` checksum tail (row sums and
/// absolute row sums, one each per p).
pub fn packed_b_len_checked(kc: usize, nc: usize, nr: usize) -> usize {
    packed_b_len(kc, nc, nr) + 2 * kc
}

/// [`pack_a`], then append the ABFT checksum tail directly after the
/// packed micro-panels: `buf[base..base+kc]` holds the alpha-folded
/// column sums of `a` (`Σ_i alpha*a[i,p]`) and `buf[base+kc..base+2kc]`
/// the matching absolute sums, where `base = packed_a_len(mc, kc, mr)`.
/// Both are accumulated in f64 **from the source view** — never from the
/// packed data — so the reference sums stay clean even if the packed
/// panels are later corrupted.
pub fn pack_a_checked<E: Elem>(a: MatView<'_, E>, buf: &mut [E], mr: usize, alpha: E) {
    let (mc, kc) = (a.rows, a.cols);
    let base = packed_a_len(mc, kc, mr);
    assert!(buf.len() >= base + 2 * kc, "pack_a_checked buffer too small");
    pack_a(a, buf, mr, alpha);
    let al = alpha.to_f64();
    for p in 0..kc {
        let col = &a.data[p * a.ld..p * a.ld + mc];
        let mut s = 0.0f64;
        let mut sa = 0.0f64;
        for &v in col {
            let v = al * v.to_f64();
            s += v;
            sa += v.abs();
        }
        buf[base + p] = E::from_f64(s);
        buf[base + kc + p] = E::from_f64(sa);
    }
}

/// [`pack_b`], then append the ABFT checksum tail after the packed
/// micro-panels: `buf[base..base+kc]` holds the row sums of `b`
/// (`Σ_j b[p,j]`) and `buf[base+kc..base+2kc]` the absolute sums, where
/// `base = packed_b_len(kc, nc, nr)`. f64-accumulated from the source
/// view, like [`pack_a_checked`].
pub fn pack_b_checked<E: Elem>(b: MatView<'_, E>, buf: &mut [E], nr: usize) {
    let (kc, nc) = (b.rows, b.cols);
    let base = packed_b_len(kc, nc, nr);
    assert!(buf.len() >= base + 2 * kc, "pack_b_checked buffer too small");
    pack_b(b, buf, nr);
    let mut s = vec![0.0f64; kc];
    let mut sa = vec![0.0f64; kc];
    for j in 0..nc {
        for (p, (sp, sap)) in s.iter_mut().zip(sa.iter_mut()).enumerate() {
            let v = b.at(p, j).to_f64();
            *sp += v;
            *sap += v.abs();
        }
    }
    for p in 0..kc {
        buf[base + p] = E::from_f64(s[p]);
        buf[base + kc + p] = E::from_f64(sa[p]);
    }
}

/// Test helper: read element (i, p) of a packed Ac.
#[cfg(test)]
pub fn packed_a_at(buf: &[f64], mr: usize, kc: usize, i: usize, p: usize) -> f64 {
    let panel = i / mr;
    let row = i % mr;
    buf[panel * mr * kc + p * mr + row]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{MatrixF64, Pcg64};

    fn packed_b_at_kc(buf: &[f64], nr: usize, kc: usize, j: usize, p: usize) -> f64 {
        let panel = j / nr;
        let col = j % nr;
        buf[panel * nr * kc + p * nr + col]
    }

    #[test]
    fn pack_a_roundtrip_exact_multiple() {
        let mut rng = Pcg64::seed(1);
        let a = MatrixF64::random(12, 5, &mut rng);
        let mr = 4;
        let mut buf = vec![f64::NAN; packed_a_len(12, 5, mr)];
        pack_a(a.view(), &mut buf, mr, 1.0);
        for i in 0..12 {
            for p in 0..5 {
                assert_eq!(packed_a_at(&buf, mr, 5, i, p), a[(i, p)]);
            }
        }
    }

    #[test]
    fn pack_a_fringe_zero_padded() {
        let mut rng = Pcg64::seed(2);
        let a = MatrixF64::random(10, 3, &mut rng);
        let mr = 4; // 10 = 2 full panels + fringe of 2
        let mut buf = vec![f64::NAN; packed_a_len(10, 3, mr)];
        pack_a(a.view(), &mut buf, mr, 1.0);
        for i in 0..10 {
            for p in 0..3 {
                assert_eq!(packed_a_at(&buf, mr, 3, i, p), a[(i, p)]);
            }
        }
        // Padded rows 10, 11 of the last panel are zero.
        for i in 10..12 {
            for p in 0..3 {
                assert_eq!(packed_a_at(&buf, mr, 3, i, p), 0.0);
            }
        }
    }

    #[test]
    fn pack_a_applies_alpha() {
        let a = MatrixF64::from_row_major(2, 2, &[1., 2., 3., 4.]);
        let mut buf = vec![0.0; packed_a_len(2, 2, 2)];
        pack_a(a.view(), &mut buf, 2, -2.0);
        assert_eq!(packed_a_at(&buf, 2, 2, 1, 1), -8.0);
    }

    #[test]
    fn pack_b_roundtrip_with_fringe() {
        let mut rng = Pcg64::seed(3);
        let b = MatrixF64::random(4, 11, &mut rng);
        let nr = 6; // 11 = 1 full panel + fringe of 5
        let mut buf = vec![f64::NAN; packed_b_len(4, 11, nr)];
        pack_b(b.view(), &mut buf, nr);
        for p in 0..4 {
            for j in 0..11 {
                assert_eq!(packed_b_at_kc(&buf, nr, 4, j, p), b[(p, j)]);
            }
            // Padding.
            assert_eq!(packed_b_at_kc(&buf, nr, 4, 11, p), 0.0);
        }
    }

    #[test]
    fn pack_b_micropanel_layout_is_row_contiguous() {
        // Within a micro-panel, row p of B occupies nr consecutive slots:
        // exactly what Figure 3 (bottom-right) highlights in blue.
        let b = MatrixF64::from_fn(3, 4, |i, j| (10 * i + j) as f64);
        let nr = 4;
        let mut buf = vec![0.0; packed_b_len(3, 4, nr)];
        pack_b(b.view(), &mut buf, nr);
        assert_eq!(&buf[0..4], &[0., 1., 2., 3.]);
        assert_eq!(&buf[4..8], &[10., 11., 12., 13.]);
        assert_eq!(&buf[8..12], &[20., 21., 22., 23.]);
    }

    #[test]
    fn packed_lengths() {
        assert_eq!(packed_a_len(10, 3, 4), 12 * 3);
        assert_eq!(packed_b_len(4, 11, 6), 12 * 4);
        assert_eq!(packed_a_len_checked(10, 3, 4), 12 * 3 + 6);
        assert_eq!(packed_b_len_checked(4, 11, 6), 12 * 4 + 8);
    }

    #[test]
    fn pack_a_checked_appends_alpha_folded_column_sums() {
        let mut rng = Pcg64::seed(7);
        let a = MatrixF64::random(10, 3, &mut rng);
        let mr = 4;
        let mut buf = vec![f64::NAN; packed_a_len_checked(10, 3, mr)];
        pack_a_checked(a.view(), &mut buf, mr, -2.0);
        // The packed panels are identical to a plain pack_a.
        for i in 0..10 {
            for p in 0..3 {
                assert_eq!(packed_a_at(&buf, mr, 3, i, p), -2.0 * a[(i, p)]);
            }
        }
        let base = packed_a_len(10, 3, mr);
        for p in 0..3 {
            let mut s = 0.0;
            let mut sa = 0.0;
            for i in 0..10 {
                s += -2.0 * a[(i, p)];
                sa += (-2.0 * a[(i, p)]).abs();
            }
            assert!((buf[base + p] - s).abs() < 1e-12);
            assert!((buf[base + 3 + p] - sa).abs() < 1e-12);
        }
    }

    #[test]
    fn pack_b_checked_appends_row_sums() {
        let mut rng = Pcg64::seed(8);
        let b = MatrixF64::random(4, 11, &mut rng);
        let nr = 6;
        let mut buf = vec![f64::NAN; packed_b_len_checked(4, 11, nr)];
        pack_b_checked(b.view(), &mut buf, nr);
        for p in 0..4 {
            for j in 0..11 {
                assert_eq!(packed_b_at_kc(&buf, nr, 4, j, p), b[(p, j)]);
            }
        }
        let base = packed_b_len(4, 11, nr);
        for p in 0..4 {
            let mut s = 0.0;
            let mut sa = 0.0;
            for j in 0..11 {
                s += b[(p, j)];
                sa += b[(p, j)].abs();
            }
            assert!((buf[base + p] - s).abs() < 1e-12);
            assert!((buf[base + 4 + p] - sa).abs() < 1e-12);
        }
    }
}
