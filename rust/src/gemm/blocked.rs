//! The blocked GEMM algorithm: five loops around packing and the
//! micro-kernel (paper Figure 3, left), generic over the element type.
//!
//! Loop structure and cache intent (paper §2.2, Figure 4):
//!
//! ```text
//! G1: jc over n in steps of nc      Bc panel -> L3
//! G2: pc over k in steps of kc      pack Bc
//! G3: ic over m in steps of mc      pack Ac -> L2
//! G4: jr over nc in steps of nr     Br micro-panel -> L1
//! G5: ir over mc in steps of mr     micro-kernel on Cr
//! ```

use crate::model::ccp::GemmConfig;
use crate::util::elem::Elem;
use crate::util::matrix::{MatView, MatViewMut};

use super::microkernel::MicroKernelImpl;
use super::packing::{pack_a, pack_b, packed_a_len, packed_b_len};

/// Reusable packing workspace (`Ac` + `Bc`). The paper stresses providing
/// "sufficiently-large workspace buffers to GEMM"; the coordinator pools
/// these so the hot path never allocates.
///
/// Storage is kept as `f64` words (8-byte aligned — the strictest
/// alignment any [`Elem`] needs) and reinterpreted per element type by
/// [`Workspace::bufs_mut`]: one pinned per-worker workspace serves both
/// the f64 and the f32 GEMM paths on a shared pool without doubling the
/// footprint. Packing always writes a slot before any kernel reads it,
/// so the stale bit patterns left by the other dtype are never observed.
#[derive(Default)]
pub struct Workspace {
    pub a_buf: Vec<f64>,
    pub b_buf: Vec<f64>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow (never shrink) to fit an f64 configuration.
    pub fn ensure(&mut self, cfg: &GemmConfig) {
        let a_need = packed_a_len(cfg.ccp.mc, cfg.ccp.kc, cfg.mk.mr);
        let b_need = packed_b_len(cfg.ccp.kc, cfg.ccp.nc, cfg.mk.nr);
        self.ensure_elems::<f64>(a_need, b_need);
    }

    /// f64 words needed to back `elems` elements of `E`.
    fn words_for<E: Elem>(elems: usize) -> usize {
        (elems * std::mem::size_of::<E>()).div_ceil(std::mem::size_of::<f64>())
    }

    /// Grow (never shrink) the backing storage to hold `a_elems` /
    /// `b_elems` elements of `E`.
    pub fn ensure_elems<E: Elem>(&mut self, a_elems: usize, b_elems: usize) {
        let aw = Self::words_for::<E>(a_elems);
        if self.a_buf.len() < aw {
            self.a_buf.resize(aw, 0.0);
        }
        let bw = Self::words_for::<E>(b_elems);
        if self.b_buf.len() < bw {
            self.b_buf.resize(bw, 0.0);
        }
    }

    /// Typed views of the packing buffers, grown to hold exactly
    /// `a_elems` / `b_elems` elements of `E`.
    pub fn bufs_mut<E: Elem>(&mut self, a_elems: usize, b_elems: usize) -> (&mut [E], &mut [E]) {
        self.ensure_elems::<E>(a_elems, b_elems);
        // SAFETY: the f64 backing store is 8-byte aligned (>= align of
        // every Elem), `ensure_elems` sized each Vec to cover the
        // requested element count, and the two fields are disjoint
        // allocations, so the reborrows cannot alias.
        unsafe {
            (
                std::slice::from_raw_parts_mut(self.a_buf.as_mut_ptr() as *mut E, a_elems),
                std::slice::from_raw_parts_mut(self.b_buf.as_mut_ptr() as *mut E, b_elems),
            )
        }
    }

    pub fn bytes(&self) -> usize {
        8 * (self.a_buf.len() + self.b_buf.len())
    }
}

/// Scale `C *= beta` (handled once, before the accumulation passes).
/// Shared by the sequential and pool-parallel drivers — the parallel path
/// (`parallel::scale_c_parallel`) splits exactly this column loop over
/// the worker pool for large C, keeping the arithmetic (and therefore
/// bitwise results) identical.
pub(crate) fn scale_c<E: Elem>(beta: E, c: &mut MatViewMut<'_, E>) {
    if beta == E::ONE {
        return;
    }
    for j in 0..c.cols {
        let col = &mut c.data[j * c.ld..j * c.ld + c.rows];
        if beta == E::ZERO {
            col.fill(E::ZERO);
        } else {
            for v in col {
                *v *= beta;
            }
        }
    }
}

/// Elements of the stack scratch used for fringe tiles in
/// [`macro_kernel`]; bounds the largest registrable micro-tile (32x32).
pub(crate) const FRINGE_SCRATCH_ELEMS: usize = 32 * 32;

/// Run the macro-kernel: loops G4/G5 over one packed (Ac, Bc) pair,
/// updating the `mc_eff x nc_eff` block of C whose (0,0) element is at
/// `c_ptr` with leading dimension `ldc`.
///
/// Raw-pointer based so the G3/G4-parallel drivers can hand disjoint
/// regions of C to worker threads (paper §2.2's loop parallelization).
///
/// # Safety
/// `c_ptr` must point to a valid column-major block of at least
/// `mc_eff x nc_eff` elements with stride `ldc >= mc_eff`, and no other
/// thread may concurrently touch the `(ir, jr)` tiles in `jr_range`.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn macro_kernel<E: Elem>(
    kernel: &MicroKernelImpl<E>,
    kc_eff: usize,
    mc_eff: usize,
    nc_eff: usize,
    a_buf: &[E],
    b_buf: &[E],
    c_ptr: *mut E,
    ldc: usize,
    jr_range: (usize, usize),
) {
    let (mr, nr) = (kernel.spec.mr, kernel.spec.nr);
    // Hard guard (not debug-only): a fringe tile is computed into a
    // fixed-size stack scratch below, and a future >32-wide kernel
    // registration must fail loudly here instead of silently corrupting
    // the stack in release builds.
    assert!(
        mr * nr <= FRINGE_SCRATCH_ELEMS,
        "micro-kernel tile {mr}x{nr} overflows the {FRINGE_SCRATCH_ELEMS}-element fringe scratch"
    );
    let (jr_lo, jr_hi) = jr_range;
    debug_assert_eq!(jr_lo % nr, 0, "jr partition must align to nr");
    let mut jr = jr_lo;
    while jr < jr_hi {
        let nr_eff = nr.min(nc_eff - jr);
        let b_panel = &b_buf[(jr / nr) * nr * kc_eff..];
        let mut ir = 0;
        while ir < mc_eff {
            let mr_eff = mr.min(mc_eff - ir);
            let a_panel = &a_buf[(ir / mr) * mr * kc_eff..];
            if mr_eff == mr && nr_eff == nr {
                // Full tile: straight into C.
                (kernel.func)(kc_eff, a_panel.as_ptr(), b_panel.as_ptr(), c_ptr.add(jr * ldc + ir), ldc);
            } else {
                // Fringe tile: compute into an mr x nr scratch (packed
                // operands are zero-padded so the excess rows/cols are
                // exact zeros), then accumulate the live region. Sized by
                // the hard assert at function entry.
                let mut scratch = [E::ZERO; FRINGE_SCRATCH_ELEMS];
                (kernel.func)(kc_eff, a_panel.as_ptr(), b_panel.as_ptr(), scratch.as_mut_ptr(), mr);
                for j in 0..nr_eff {
                    for i in 0..mr_eff {
                        *c_ptr.add((jr + j) * ldc + ir + i) += scratch[j * mr + i];
                    }
                }
            }
            ir += mr;
        }
        jr += nr;
    }
}

/// Sequential blocked GEMM: `C = alpha * A * B + beta * C` with explicit
/// configuration (micro-kernel + CCPs). This is loop G1..G5 verbatim,
/// for any element type.
pub fn gemm_blocked<E: Elem>(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl<E>,
    alpha: E,
    a: MatView<'_, E>,
    b: MatView<'_, E>,
    beta: E,
    c: &mut MatViewMut<'_, E>,
    ws: &mut Workspace,
) {
    assert_eq!(kernel.spec, cfg.mk, "kernel/config shape mismatch");
    assert_eq!(a.cols, b.rows, "inner dimension mismatch");
    assert_eq!(c.rows, a.rows, "C row mismatch");
    assert_eq!(c.cols, b.cols, "C col mismatch");
    let (m, n, k) = (a.rows, b.cols, a.cols);
    scale_c(beta, c);
    if m == 0 || n == 0 || k == 0 || alpha == E::ZERO {
        return;
    }
    let ccp = cfg.ccp.clamp_to(crate::model::GemmDims::new(m, n, k));
    let (mc, nc, kc) = (ccp.mc, ccp.nc, ccp.kc);
    let a_need = packed_a_len(mc, kc, cfg.mk.mr);
    let b_need = packed_b_len(kc, nc, cfg.mk.nr);
    let (a_buf, b_buf) = ws.bufs_mut::<E>(a_need, b_need);

    let mut jc = 0; // Loop G1
    while jc < n {
        let nc_eff = nc.min(n - jc);
        let mut pc = 0; // Loop G2
        while pc < k {
            let kc_eff = kc.min(k - pc);
            pack_b(b.sub(pc, jc, kc_eff, nc_eff), b_buf, cfg.mk.nr);
            let mut ic = 0; // Loop G3
            while ic < m {
                let mc_eff = mc.min(m - ic);
                pack_a(a.sub(ic, pc, mc_eff, kc_eff), a_buf, cfg.mk.mr, alpha);
                let c_ptr = unsafe { c.data.as_mut_ptr().add(jc * c.ld + ic) };
                unsafe {
                    macro_kernel(
                        kernel,
                        kc_eff,
                        mc_eff,
                        nc_eff,
                        a_buf,
                        b_buf,
                        c_ptr,
                        c.ld,
                        (0, nc_eff),
                    )
                };
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::gemm_reference;
    use crate::gemm::microkernel::{for_shape, for_shape_f32, registry};
    use crate::model::{Ccp, MicroKernel};
    use crate::util::{MatrixF32, MatrixF64, Pcg64};

    fn run_case(mk: MicroKernel, ccp: Ccp, m: usize, n: usize, k: usize, alpha: f64, beta: f64) {
        let kernel = for_shape(mk).expect("kernel registered");
        let cfg = GemmConfig { mk, ccp };
        let mut rng = Pcg64::seed((m * 31 + n * 7 + k) as u64);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let mut c = MatrixF64::random(m, n, &mut rng);
        let mut expect = c.clone();
        gemm_reference(alpha, a.view(), b.view(), beta, &mut expect.view_mut());
        let mut ws = Workspace::new();
        gemm_blocked(&cfg, &kernel, alpha, a.view(), b.view(), beta, &mut c.view_mut(), &mut ws);
        let scale = (k as f64).max(1.0);
        assert!(
            c.max_abs_diff(&expect) < 1e-12 * scale,
            "blocked GEMM {}x{}x{} mk={} ccp={} diverges",
            m,
            n,
            k,
            mk,
            ccp
        );
    }

    #[test]
    fn matches_reference_square() {
        run_case(MicroKernel::new(8, 6), Ccp::new(64, 96, 32), 100, 100, 100, 1.0, 1.0);
    }

    #[test]
    fn matches_reference_awkward_sizes() {
        // Dimensions NOT multiples of anything, CCPs bigger than dims,
        // CCPs of 1, alpha/beta combinations.
        run_case(MicroKernel::new(8, 6), Ccp::new(37, 29, 13), 61, 53, 47, 1.0, 0.0);
        run_case(MicroKernel::new(6, 8), Ccp::new(1000, 1000, 1000), 23, 19, 17, -0.5, 2.0);
        run_case(MicroKernel::new(12, 4), Ccp::new(24, 16, 8), 25, 17, 9, 2.0, 1.0);
        run_case(MicroKernel::new(4, 12), Ccp::new(12, 24, 5), 4, 12, 5, 1.0, 1.0);
        run_case(MicroKernel::new(10, 4), Ccp::new(20, 8, 3), 11, 5, 4, 1.0, -1.0);
    }

    #[test]
    fn matches_reference_skinny_k_paper_shape() {
        // The paper's shape of interest: large m=n, small k.
        run_case(MicroKernel::new(8, 6), Ccp::new(768, 2000, 64), 200, 200, 64, 1.0, 1.0);
    }

    #[test]
    fn one_by_one() {
        run_case(MicroKernel::new(1, 1), Ccp::new(1, 1, 1), 1, 1, 1, 3.0, 0.5);
        run_case(MicroKernel::new(1, 1), Ccp::new(2, 2, 2), 3, 3, 3, 1.0, 1.0);
    }

    #[test]
    fn alpha_zero_only_scales() {
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(8, 8, 8) };
        let mut rng = Pcg64::seed(9);
        let a = MatrixF64::random(10, 10, &mut rng);
        let b = MatrixF64::random(10, 10, &mut rng);
        let mut c = MatrixF64::random(10, 10, &mut rng);
        let expect = MatrixF64::from_fn(10, 10, |i, j| 2.0 * c[(i, j)]);
        let mut ws = Workspace::new();
        gemm_blocked(&cfg, &kernel, 0.0, a.view(), b.view(), 2.0, &mut c.view_mut(), &mut ws);
        assert!(c.max_abs_diff(&expect) < 1e-13);
    }

    #[test]
    fn every_kernel_runs_the_blocked_path() {
        for imp in registry() {
            if imp.prefetch {
                continue;
            }
            let ccp = Ccp::new(3 * imp.spec.mr, 2 * imp.spec.nr, 16);
            run_case(imp.spec, ccp, 2 * imp.spec.mr + 3, 2 * imp.spec.nr + 1, 33, 1.0, 1.0);
        }
    }

    #[test]
    fn f32_blocked_matches_f32_reference_in_one_workspace() {
        // One Workspace serves an f64 call and then an f32 call (the
        // shared-pool reuse pattern): the f32 results must match the f32
        // reference regardless of the stale f64 bits in the buffers.
        let mut ws = Workspace::new();
        run_case_in_ws(&mut ws);
        let mk = MicroKernel::new(16, 6);
        let kernel = for_shape_f32(mk).expect("f32 kernel registered");
        let cfg = GemmConfig { mk, ccp: Ccp::new(48, 36, 16) };
        let mut rng = Pcg64::seed(77);
        let (m, n, k) = (61, 53, 29);
        let a = MatrixF32::random(m, k, &mut rng);
        let b = MatrixF32::random(k, n, &mut rng);
        let mut c = MatrixF32::random(m, n, &mut rng);
        let mut expect = c.clone();
        gemm_reference(1.0f32, a.view(), b.view(), 1.0f32, &mut expect.view_mut());
        gemm_blocked(&cfg, &kernel, 1.0f32, a.view(), b.view(), 1.0f32, &mut c.view_mut(), &mut ws);
        assert!(
            c.max_abs_diff(&expect) < 1e-4,
            "f32 blocked GEMM diverges: {}",
            c.max_abs_diff(&expect)
        );
    }

    fn run_case_in_ws(ws: &mut Workspace) {
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(32, 24, 16) };
        let mut rng = Pcg64::seed(5);
        let a = MatrixF64::random(40, 20, &mut rng);
        let b = MatrixF64::random(20, 30, &mut rng);
        let mut c = MatrixF64::zeros(40, 30);
        gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), 0.0, &mut c.view_mut(), ws);
    }

    #[test]
    fn workspace_reuse_grows_monotonically() {
        let mut ws = Workspace::new();
        let cfg_small = GemmConfig { mk: MicroKernel::new(8, 6), ccp: Ccp::new(16, 12, 8) };
        let cfg_big = GemmConfig { mk: MicroKernel::new(8, 6), ccp: Ccp::new(64, 48, 32) };
        ws.ensure(&cfg_small);
        let small = ws.bytes();
        ws.ensure(&cfg_big);
        let big = ws.bytes();
        ws.ensure(&cfg_small);
        assert!(big > small);
        assert_eq!(ws.bytes(), big, "workspace must not shrink");
    }

    #[test]
    fn workspace_typed_views_pack_halved_words_for_f32() {
        // 10 f32 elements fit in 5 f64 words (rounded up); the same
        // request in f64 takes 10 words.
        let mut ws = Workspace::new();
        ws.ensure_elems::<f32>(10, 3);
        assert_eq!(ws.a_buf.len(), 5);
        assert_eq!(ws.b_buf.len(), 2);
        let (a32, b32) = ws.bufs_mut::<f32>(10, 3);
        assert_eq!((a32.len(), b32.len()), (10, 3));
        a32.fill(1.5f32);
        b32.fill(-2.0f32);
        assert!(a32.iter().all(|&v| v == 1.5));
        let mut ws2 = Workspace::new();
        ws2.ensure_elems::<f64>(10, 3);
        assert_eq!(ws2.a_buf.len(), 10);
    }

    #[test]
    #[should_panic(expected = "fringe scratch")]
    fn oversized_micro_tile_is_rejected_in_release_too() {
        // A hypothetical >32-wide kernel must be refused by a hard assert
        // (the seed only debug_assert-ed, silently corrupting the stack
        // in release builds).
        let base = for_shape(MicroKernel::new(8, 6)).unwrap();
        let fake = MicroKernelImpl { spec: MicroKernel::new(33, 33), ..base };
        let cfg = GemmConfig { mk: fake.spec, ccp: Ccp::new(33, 33, 8) };
        let a = MatrixF64::zeros(4, 4);
        let b = MatrixF64::zeros(4, 4);
        let mut c = MatrixF64::zeros(4, 4);
        let mut ws = Workspace::new();
        gemm_blocked(&cfg, &fake, 1.0, a.view(), b.view(), 0.0, &mut c.view_mut(), &mut ws);
    }

    #[test]
    fn empty_dims_are_noops() {
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(8, 8, 8) };
        let a = MatrixF64::zeros(0, 5);
        let b = MatrixF64::zeros(5, 0);
        let mut c = MatrixF64::zeros(0, 0);
        let mut ws = Workspace::new();
        gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), 1.0, &mut c.view_mut(), &mut ws);
    }
}
