//! Micro-kernel implementations (paper §2.3, §3.4 and Figure 7).
//!
//! Every kernel computes `Cr += Ar * Br` over packed micro-panels:
//! `Ar` is `mr x kc` (column-contiguous), `Br` is `kc x nr`
//! (row-contiguous), `Cr` is an `mr x nr` tile of the column-major output
//! with leading dimension `ldc`. Alpha is folded into `Ar` by packing.
//!
//! Two families are provided, mirroring the paper's intrinsics-vs-assembly
//! discussion:
//!
//! - **AVX2+FMA kernels** (`avx2_*`): the broadcast coding style of paper
//!   Figure 7 translated to x86 — `MR/4` ymm loads of the `Ar` column, one
//!   `broadcast_sd` per `Br` element, FMA into an `MR/4 x NR` accumulator
//!   file. Register budget (16 ymm) checks: 8x6 = 12+2+1 = 15,
//!   12x4 = 12+3+1 = 16, 4x12 = 12+1+1 = 14.
//! - **Portable scalar kernels** (`scalar_*`): const-generic Rust that the
//!   compiler auto-vectorizes; these cover shapes whose `mr` is not a
//!   multiple of the AVX2 lane count (e.g. the paper's ARM `MK6x8`) and
//!   any host without AVX2.
//!
//! Prefetch variants mirror the paper's BLIS-with-prefetching comparison
//! on the AMD platform (§4.1): identical arithmetic plus software
//! prefetches of the next `Ar`/`Br` lines and the `Cr` tile.

use crate::model::MicroKernel;

/// Signature of a micro-kernel over packed operands.
///
/// # Safety
/// `a` must point to `mr*kc` packed elements, `b` to `kc*nr`, and `c` to a
/// column-major `mr x nr` tile with leading dimension `ldc >= mr`.
pub type MicroKernelFn = unsafe fn(kc: usize, a: *const f64, b: *const f64, c: *mut f64, ldc: usize);

/// A registered micro-kernel implementation.
#[derive(Clone, Copy)]
pub struct MicroKernelImpl {
    pub spec: MicroKernel,
    pub func: MicroKernelFn,
    pub name: &'static str,
    /// True for the intrinsics (SIMD) family, false for portable scalar.
    pub simd: bool,
    /// True when the kernel issues software prefetches.
    pub prefetch: bool,
}

impl std::fmt::Debug for MicroKernelImpl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MicroKernelImpl({})", self.name)
    }
}

// ---------------------------------------------------------------------------
// Portable const-generic scalar kernels
// ---------------------------------------------------------------------------

/// Portable kernel: full unroll over an `MR x NR` accumulator tile.
///
/// # Safety
/// See [`MicroKernelFn`].
unsafe fn scalar_kernel<const MR: usize, const NR: usize>(
    kc: usize,
    a: *const f64,
    b: *const f64,
    c: *mut f64,
    ldc: usize,
) {
    let mut acc = [[0.0f64; MR]; NR];
    let mut ap = a;
    let mut bp = b;
    for _ in 0..kc {
        // One column of Ar and one row of Br per iteration (Figure 3,
        // top-right): a sequence of rank-1 updates.
        let mut av = [0.0f64; MR];
        for (i, v) in av.iter_mut().enumerate() {
            *v = *ap.add(i);
        }
        for j in 0..NR {
            let bv = *bp.add(j);
            for i in 0..MR {
                // Plain mul+add, NOT f64::mul_add: without +fma in the
                // target features, mul_add lowers to a libm call (measured
                // 70x slower); mul+add auto-vectorizes cleanly.
                acc[j][i] += av[i] * bv;
            }
        }
        ap = ap.add(MR);
        bp = bp.add(NR);
    }
    for j in 0..NR {
        let cj = c.add(j * ldc);
        for i in 0..MR {
            *cj.add(i) += acc[j][i];
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    /// AVX2 kernel over an `(4*MRV) x NR` tile; `PF` enables software
    /// prefetching of upcoming packed data and the C tile.
    ///
    /// # Safety
    /// Caller must ensure `avx2` and `fma` are available and the pointer
    /// contracts of [`super::MicroKernelFn`] hold with `mr = 4 * MRV`.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn kernel<const MRV: usize, const NR: usize, const PF: bool>(
        kc: usize,
        a: *const f64,
        b: *const f64,
        c: *mut f64,
        ldc: usize,
    ) {
        let mr = 4 * MRV;
        let mut acc = [[_mm256_setzero_pd(); MRV]; NR];
        if PF {
            // Prefetch the C tile so the final accumulate does not stall
            // (the BLIS kernels prefetch C early for the same reason).
            for j in 0..NR {
                _mm_prefetch::<_MM_HINT_T0>(c.add(j * ldc) as *const i8);
            }
        }
        let mut ap = a;
        let mut bp = b;
        for p in 0..kc {
            if PF && p + 8 < kc {
                _mm_prefetch::<_MM_HINT_T0>(ap.add(8 * mr) as *const i8);
                _mm_prefetch::<_MM_HINT_T0>(bp.add(8 * NR) as *const i8);
            }
            let mut av = [_mm256_setzero_pd(); MRV];
            for (i, v) in av.iter_mut().enumerate() {
                *v = _mm256_loadu_pd(ap.add(4 * i));
            }
            // NR broadcast+FMA groups: the WAR-aware ordering of paper
            // Figure 7 (all loads of the iteration before the updates).
            for j in 0..NR {
                let bv = _mm256_broadcast_sd(&*bp.add(j));
                for i in 0..MRV {
                    acc[j][i] = _mm256_fmadd_pd(av[i], bv, acc[j][i]);
                }
            }
            ap = ap.add(mr);
            bp = bp.add(NR);
        }
        for j in 0..NR {
            let cj = c.add(j * ldc);
            for i in 0..MRV {
                let cur = _mm256_loadu_pd(cj.add(4 * i));
                _mm256_storeu_pd(cj.add(4 * i), _mm256_add_pd(cur, acc[j][i]));
            }
        }
    }
}

/// Wrap an AVX2 const-generic instantiation in a plain `unsafe fn` so it
/// can live in the registry (feature detection happens at registration).
macro_rules! avx2_entry {
    ($name:ident, $mrv:literal, $nr:literal, $pf:literal) => {
        /// # Safety
        /// AVX2+FMA must be available; pointer contracts per [`MicroKernelFn`].
        #[cfg(target_arch = "x86_64")]
        unsafe fn $name(kc: usize, a: *const f64, b: *const f64, c: *mut f64, ldc: usize) {
            avx2::kernel::<$mrv, $nr, $pf>(kc, a, b, c, ldc)
        }
    };
}

avx2_entry!(avx2_8x6, 2, 6, false);
avx2_entry!(avx2_8x6_pf, 2, 6, true);
avx2_entry!(avx2_12x4, 3, 4, false);
avx2_entry!(avx2_12x4_pf, 3, 4, true);
avx2_entry!(avx2_4x12, 1, 12, false);
avx2_entry!(avx2_8x4, 2, 4, false);
avx2_entry!(avx2_4x8, 1, 8, false);
avx2_entry!(avx2_4x10, 1, 10, false);
avx2_entry!(avx2_8x2, 2, 2, false);
avx2_entry!(avx2_4x4, 1, 4, false);

macro_rules! scalar_entry {
    ($name:ident, $mr:literal, $nr:literal) => {
        /// # Safety
        /// Pointer contracts per [`MicroKernelFn`].
        unsafe fn $name(kc: usize, a: *const f64, b: *const f64, c: *mut f64, ldc: usize) {
            scalar_kernel::<$mr, $nr>(kc, a, b, c, ldc)
        }
    };
}

scalar_entry!(scalar_6x8, 6, 8);
scalar_entry!(scalar_8x6, 8, 6);
scalar_entry!(scalar_12x4, 12, 4);
scalar_entry!(scalar_4x12, 4, 12);
scalar_entry!(scalar_10x4, 10, 4);
scalar_entry!(scalar_4x10, 4, 10);
scalar_entry!(scalar_8x8, 8, 8);
scalar_entry!(scalar_4x4, 4, 4);
scalar_entry!(scalar_2x2, 2, 2);
scalar_entry!(scalar_1x1, 1, 1);

/// True when the host can run the AVX2+FMA family.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Build the registry of micro-kernels runnable on this host.
/// SIMD kernels are listed first so name-free lookups prefer them.
pub fn registry() -> Vec<MicroKernelImpl> {
    let mut v: Vec<MicroKernelImpl> = Vec::new();
    let mk = MicroKernel::new;
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        let simd = |spec, func, name| MicroKernelImpl { spec, func, name, simd: true, prefetch: false };
        v.push(simd(mk(8, 6), avx2_8x6 as MicroKernelFn, "avx2_8x6"));
        v.push(MicroKernelImpl { spec: mk(8, 6), func: avx2_8x6_pf, name: "avx2_8x6_pf", simd: true, prefetch: true });
        v.push(simd(mk(12, 4), avx2_12x4, "avx2_12x4"));
        v.push(MicroKernelImpl { spec: mk(12, 4), func: avx2_12x4_pf, name: "avx2_12x4_pf", simd: true, prefetch: true });
        v.push(simd(mk(4, 12), avx2_4x12, "avx2_4x12"));
        v.push(simd(mk(8, 4), avx2_8x4, "avx2_8x4"));
        v.push(simd(mk(4, 8), avx2_4x8, "avx2_4x8"));
        v.push(simd(mk(4, 10), avx2_4x10, "avx2_4x10"));
        v.push(simd(mk(8, 2), avx2_8x2, "avx2_8x2"));
        v.push(simd(mk(4, 4), avx2_4x4, "avx2_4x4"));
    }
    let scalar = |spec, func, name| MicroKernelImpl { spec, func, name, simd: false, prefetch: false };
    v.push(scalar(mk(6, 8), scalar_6x8 as MicroKernelFn, "scalar_6x8"));
    v.push(scalar(mk(8, 6), scalar_8x6, "scalar_8x6"));
    v.push(scalar(mk(12, 4), scalar_12x4, "scalar_12x4"));
    v.push(scalar(mk(4, 12), scalar_4x12, "scalar_4x12"));
    v.push(scalar(mk(10, 4), scalar_10x4, "scalar_10x4"));
    v.push(scalar(mk(4, 10), scalar_4x10, "scalar_4x10"));
    v.push(scalar(mk(8, 8), scalar_8x8, "scalar_8x8"));
    v.push(scalar(mk(4, 4), scalar_4x4, "scalar_4x4"));
    v.push(scalar(mk(2, 2), scalar_2x2, "scalar_2x2"));
    v.push(scalar(mk(1, 1), scalar_1x1, "scalar_1x1"));
    v
}

/// Find a kernel by name.
pub fn by_name(name: &str) -> Option<MicroKernelImpl> {
    registry().into_iter().find(|k| k.name == name)
}

/// Find the preferred (first-registered) kernel for a shape.
pub fn for_shape(spec: MicroKernel) -> Option<MicroKernelImpl> {
    registry().into_iter().find(|k| k.spec == spec && !k.prefetch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::packing::{pack_a, pack_b, packed_a_len, packed_b_len};
    use crate::util::{MatrixF64, Pcg64};

    /// Drive one micro-kernel over a random full-tile problem and compare
    /// with the naive product.
    fn check_kernel(imp: &MicroKernelImpl, kc: usize) {
        let (mr, nr) = (imp.spec.mr, imp.spec.nr);
        let mut rng = Pcg64::seed(kc as u64 * 31 + mr as u64 * 7 + nr as u64);
        let a = MatrixF64::random(mr, kc, &mut rng);
        let b = MatrixF64::random(kc, nr, &mut rng);
        let mut c = MatrixF64::random(mr, nr, &mut rng);
        let mut expect = c.clone();
        crate::gemm::gemm_reference(1.0, a.view(), b.view(), 1.0, &mut expect.view_mut());

        let mut abuf = vec![0.0; packed_a_len(mr, kc, mr)];
        let mut bbuf = vec![0.0; packed_b_len(kc, nr, nr)];
        pack_a(a.view(), &mut abuf, mr, 1.0);
        pack_b(b.view(), &mut bbuf, nr);
        let ldc = c.ld();
        unsafe { (imp.func)(kc, abuf.as_ptr(), bbuf.as_ptr(), c.as_mut_ptr(), ldc) };
        assert!(
            c.max_abs_diff(&expect) < 1e-11,
            "kernel {} kc={} diverges from reference",
            imp.name,
            kc
        );
    }

    #[test]
    fn every_registered_kernel_matches_reference() {
        for imp in registry() {
            for kc in [1, 2, 7, 64, 129] {
                check_kernel(&imp, kc);
            }
        }
    }

    #[test]
    fn kc_zero_is_identity() {
        for imp in registry().into_iter().take(3) {
            let (mr, nr) = (imp.spec.mr, imp.spec.nr);
            let mut c = MatrixF64::from_fn(mr, nr, |i, j| (i + 10 * j) as f64);
            let orig = c.clone();
            let abuf = vec![0.0; mr];
            let bbuf = vec![0.0; nr];
            let ldc = c.ld();
            unsafe { (imp.func)(0, abuf.as_ptr(), bbuf.as_ptr(), c.as_mut_ptr(), ldc) };
            assert_eq!(c, orig, "{} with kc=0 must not touch C", imp.name);
        }
    }

    #[test]
    fn registry_contains_paper_shapes() {
        let shapes: Vec<(usize, usize)> = registry().iter().map(|k| (k.spec.mr, k.spec.nr)).collect();
        for s in [(6, 8), (8, 6), (12, 4), (4, 12), (10, 4), (4, 10)] {
            assert!(shapes.contains(&s), "missing MK{}x{}", s.0, s.1);
        }
    }

    #[test]
    fn lookup_by_name_and_shape() {
        assert!(by_name("scalar_6x8").is_some());
        assert!(by_name("does_not_exist").is_none());
        let k = for_shape(MicroKernel::new(8, 6)).unwrap();
        assert_eq!((k.spec.mr, k.spec.nr), (8, 6));
        if avx2_available() {
            assert!(k.simd, "SIMD kernel must be preferred for 8x6");
        }
    }

    #[test]
    fn prefetch_variant_same_numerics() {
        if !avx2_available() {
            return;
        }
        let plain = by_name("avx2_8x6").unwrap();
        let pf = by_name("avx2_8x6_pf").unwrap();
        for kc in [3, 64] {
            check_kernel(&plain, kc);
            check_kernel(&pf, kc);
        }
    }
}
