//! Micro-kernel implementations (paper §2.3, §3.4 and Figure 7).
//!
//! Every kernel computes `Cr += Ar * Br` over packed micro-panels:
//! `Ar` is `mr x kc` (column-contiguous), `Br` is `kc x nr`
//! (row-contiguous), `Cr` is an `mr x nr` tile of the column-major output
//! with leading dimension `ldc`. Alpha is folded into `Ar` by packing.
//!
//! Kernels are generic over the element type ([`MicroKernelImpl<E>`]);
//! three families are provided:
//!
//! - **AVX2+FMA f64 kernels** (`avx2_*`): the broadcast coding style of
//!   paper Figure 7 translated to x86 — `MR/4` ymm loads of the `Ar`
//!   column, one `broadcast_sd` per `Br` element, FMA into an
//!   `MR/4 x NR` accumulator file. Register budget (16 ymm) checks:
//!   8x6 = 12+2+1 = 15, 12x4 = 12+3+1 = 16, 4x12 = 12+1+1 = 14.
//! - **AVX2+FMA f32 kernels** (`avx2s_*`): the same coding style at 8
//!   lanes per ymm, so the natural tiles double in `mr`:
//!   16x6 = 12+2+1 = 15, 8x8 = 8+1+1 = 10, 16x4 = 8+2+1 = 11,
//!   8x12 = 12+1+2 = 15.
//! - **Portable scalar kernels** (`scalar_*` / `scalar32_*`):
//!   const-generic Rust that the compiler auto-vectorizes; these cover
//!   shapes whose `mr` is not a multiple of the AVX2 lane count (e.g.
//!   the paper's ARM `MK6x8`) and any host without AVX2.
//!
//! Prefetch variants mirror the paper's BLIS-with-prefetching comparison
//! on the AMD platform (§4.1): identical arithmetic plus software
//! prefetches of the next `Ar`/`Br` lines and the `Cr` tile.
//!
//! The host registries are built **once** per element type (feature
//! detection runs once, memoized in a `OnceLock`); `registry()` /
//! `for_shape()` / `by_name()` are lookups against the memoized table.

use std::sync::OnceLock;

use crate::model::MicroKernel;
use crate::util::elem::Elem;

/// Signature of a micro-kernel over packed operands of element type `E`.
///
/// # Safety
/// `a` must point to `mr*kc` packed elements, `b` to `kc*nr`, and `c` to a
/// column-major `mr x nr` tile with leading dimension `ldc >= mr`.
pub type MicroKernelFnOf<E> = unsafe fn(kc: usize, a: *const E, b: *const E, c: *mut E, ldc: usize);

/// The f64 kernel signature (the historical name).
pub type MicroKernelFn = MicroKernelFnOf<f64>;

/// A registered micro-kernel implementation for element type `E`
/// (default `f64`, so pre-generic code keeps compiling unchanged).
pub struct MicroKernelImpl<E = f64> {
    pub spec: MicroKernel,
    pub func: MicroKernelFnOf<E>,
    pub name: &'static str,
    /// True for the intrinsics (SIMD) family, false for portable scalar.
    pub simd: bool,
    /// True when the kernel issues software prefetches.
    pub prefetch: bool,
}

// Manual Clone/Copy: the derive would bound them on `E: Copy` even
// though only a fn pointer over E is stored.
impl<E> Clone for MicroKernelImpl<E> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<E> Copy for MicroKernelImpl<E> {}

impl<E> std::fmt::Debug for MicroKernelImpl<E> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MicroKernelImpl({})", self.name)
    }
}

// ---------------------------------------------------------------------------
// Portable const-generic scalar kernels (any Elem)
// ---------------------------------------------------------------------------

/// Portable kernel: full unroll over an `MR x NR` accumulator tile.
///
/// # Safety
/// See [`MicroKernelFnOf`].
unsafe fn scalar_kernel<E: Elem, const MR: usize, const NR: usize>(
    kc: usize,
    a: *const E,
    b: *const E,
    c: *mut E,
    ldc: usize,
) {
    let mut acc = [[E::ZERO; MR]; NR];
    let mut ap = a;
    let mut bp = b;
    for _ in 0..kc {
        // One column of Ar and one row of Br per iteration (Figure 3,
        // top-right): a sequence of rank-1 updates.
        let mut av = [E::ZERO; MR];
        for (i, v) in av.iter_mut().enumerate() {
            *v = *ap.add(i);
        }
        for j in 0..NR {
            let bv = *bp.add(j);
            for i in 0..MR {
                // Plain mul+add, NOT mul_add: without +fma in the
                // target features, mul_add lowers to a libm call (measured
                // 70x slower); mul+add auto-vectorizes cleanly.
                acc[j][i] += av[i] * bv;
            }
        }
        ap = ap.add(MR);
        bp = bp.add(NR);
    }
    for j in 0..NR {
        let cj = c.add(j * ldc);
        for i in 0..MR {
            *cj.add(i) += acc[j][i];
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA kernels, f64
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    /// AVX2 f64 kernel over an `(4*MRV) x NR` tile; `PF` enables software
    /// prefetching of upcoming packed data and the C tile.
    ///
    /// # Safety
    /// Caller must ensure `avx2` and `fma` are available and the pointer
    /// contracts of [`super::MicroKernelFnOf`] hold with `mr = 4 * MRV`.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn kernel<const MRV: usize, const NR: usize, const PF: bool>(
        kc: usize,
        a: *const f64,
        b: *const f64,
        c: *mut f64,
        ldc: usize,
    ) {
        let mr = 4 * MRV;
        let mut acc = [[_mm256_setzero_pd(); MRV]; NR];
        if PF {
            // Prefetch the C tile so the final accumulate does not stall
            // (the BLIS kernels prefetch C early for the same reason).
            for j in 0..NR {
                _mm_prefetch::<_MM_HINT_T0>(c.add(j * ldc) as *const i8);
            }
        }
        let mut ap = a;
        let mut bp = b;
        for p in 0..kc {
            if PF && p + 8 < kc {
                _mm_prefetch::<_MM_HINT_T0>(ap.add(8 * mr) as *const i8);
                _mm_prefetch::<_MM_HINT_T0>(bp.add(8 * NR) as *const i8);
            }
            let mut av = [_mm256_setzero_pd(); MRV];
            for (i, v) in av.iter_mut().enumerate() {
                *v = _mm256_loadu_pd(ap.add(4 * i));
            }
            // NR broadcast+FMA groups: the WAR-aware ordering of paper
            // Figure 7 (all loads of the iteration before the updates).
            for j in 0..NR {
                let bv = _mm256_broadcast_sd(&*bp.add(j));
                for i in 0..MRV {
                    acc[j][i] = _mm256_fmadd_pd(av[i], bv, acc[j][i]);
                }
            }
            ap = ap.add(mr);
            bp = bp.add(NR);
        }
        for j in 0..NR {
            let cj = c.add(j * ldc);
            for i in 0..MRV {
                let cur = _mm256_loadu_pd(cj.add(4 * i));
                _mm256_storeu_pd(cj.add(4 * i), _mm256_add_pd(cur, acc[j][i]));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA kernels, f32 (8 lanes per ymm: twice the f64 tile height)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2s {
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    /// AVX2 f32 kernel over an `(8*MRV) x NR` tile; `PF` enables software
    /// prefetching. Identical structure to the f64 kernel, one `ps`
    /// vector per 8 rows.
    ///
    /// # Safety
    /// Caller must ensure `avx2` and `fma` are available and the pointer
    /// contracts of [`super::MicroKernelFnOf`] hold with `mr = 8 * MRV`.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn kernel<const MRV: usize, const NR: usize, const PF: bool>(
        kc: usize,
        a: *const f32,
        b: *const f32,
        c: *mut f32,
        ldc: usize,
    ) {
        let mr = 8 * MRV;
        let mut acc = [[_mm256_setzero_ps(); MRV]; NR];
        if PF {
            for j in 0..NR {
                _mm_prefetch::<_MM_HINT_T0>(c.add(j * ldc) as *const i8);
            }
        }
        let mut ap = a;
        let mut bp = b;
        for p in 0..kc {
            if PF && p + 8 < kc {
                _mm_prefetch::<_MM_HINT_T0>(ap.add(8 * mr) as *const i8);
                _mm_prefetch::<_MM_HINT_T0>(bp.add(8 * NR) as *const i8);
            }
            let mut av = [_mm256_setzero_ps(); MRV];
            for (i, v) in av.iter_mut().enumerate() {
                *v = _mm256_loadu_ps(ap.add(8 * i));
            }
            for j in 0..NR {
                let bv = _mm256_broadcast_ss(&*bp.add(j));
                for i in 0..MRV {
                    acc[j][i] = _mm256_fmadd_ps(av[i], bv, acc[j][i]);
                }
            }
            ap = ap.add(mr);
            bp = bp.add(NR);
        }
        for j in 0..NR {
            let cj = c.add(j * ldc);
            for i in 0..MRV {
                let cur = _mm256_loadu_ps(cj.add(8 * i));
                _mm256_storeu_ps(cj.add(8 * i), _mm256_add_ps(cur, acc[j][i]));
            }
        }
    }
}

/// Wrap an AVX2 const-generic instantiation in a plain `unsafe fn` so it
/// can live in the registry (feature detection happens at registration).
macro_rules! avx2_entry {
    ($name:ident, $mrv:literal, $nr:literal, $pf:literal) => {
        /// # Safety
        /// AVX2+FMA must be available; pointer contracts per [`MicroKernelFnOf`].
        #[cfg(target_arch = "x86_64")]
        unsafe fn $name(kc: usize, a: *const f64, b: *const f64, c: *mut f64, ldc: usize) {
            avx2::kernel::<$mrv, $nr, $pf>(kc, a, b, c, ldc)
        }
    };
}

avx2_entry!(avx2_8x6, 2, 6, false);
avx2_entry!(avx2_8x6_pf, 2, 6, true);
avx2_entry!(avx2_12x4, 3, 4, false);
avx2_entry!(avx2_12x4_pf, 3, 4, true);
avx2_entry!(avx2_4x12, 1, 12, false);
avx2_entry!(avx2_8x4, 2, 4, false);
avx2_entry!(avx2_4x8, 1, 8, false);
avx2_entry!(avx2_4x10, 1, 10, false);
avx2_entry!(avx2_8x2, 2, 2, false);
avx2_entry!(avx2_4x4, 1, 4, false);

/// As [`avx2_entry`] but for the f32 family (`mr = 8 * MRV`).
macro_rules! avx2s_entry {
    ($name:ident, $mrv:literal, $nr:literal, $pf:literal) => {
        /// # Safety
        /// AVX2+FMA must be available; pointer contracts per [`MicroKernelFnOf`].
        #[cfg(target_arch = "x86_64")]
        unsafe fn $name(kc: usize, a: *const f32, b: *const f32, c: *mut f32, ldc: usize) {
            avx2s::kernel::<$mrv, $nr, $pf>(kc, a, b, c, ldc)
        }
    };
}

avx2s_entry!(avx2s_16x6, 2, 6, false);
avx2s_entry!(avx2s_16x6_pf, 2, 6, true);
avx2s_entry!(avx2s_8x8, 1, 8, false);
avx2s_entry!(avx2s_16x4, 2, 4, false);
avx2s_entry!(avx2s_8x12, 1, 12, false);
avx2s_entry!(avx2s_8x6, 1, 6, false);
avx2s_entry!(avx2s_8x4, 1, 4, false);

macro_rules! scalar_entry {
    ($name:ident, $mr:literal, $nr:literal) => {
        /// # Safety
        /// Pointer contracts per [`MicroKernelFnOf`].
        unsafe fn $name(kc: usize, a: *const f64, b: *const f64, c: *mut f64, ldc: usize) {
            scalar_kernel::<f64, $mr, $nr>(kc, a, b, c, ldc)
        }
    };
}

scalar_entry!(scalar_6x8, 6, 8);
scalar_entry!(scalar_8x6, 8, 6);
scalar_entry!(scalar_12x4, 12, 4);
scalar_entry!(scalar_4x12, 4, 12);
scalar_entry!(scalar_10x4, 10, 4);
scalar_entry!(scalar_4x10, 4, 10);
scalar_entry!(scalar_8x8, 8, 8);
scalar_entry!(scalar_4x4, 4, 4);
scalar_entry!(scalar_2x2, 2, 2);
scalar_entry!(scalar_1x1, 1, 1);

/// As [`scalar_entry`] but instantiated at f32.
macro_rules! scalar32_entry {
    ($name:ident, $mr:literal, $nr:literal) => {
        /// # Safety
        /// Pointer contracts per [`MicroKernelFnOf`].
        unsafe fn $name(kc: usize, a: *const f32, b: *const f32, c: *mut f32, ldc: usize) {
            scalar_kernel::<f32, $mr, $nr>(kc, a, b, c, ldc)
        }
    };
}

scalar32_entry!(scalar32_16x6, 16, 6);
scalar32_entry!(scalar32_8x12, 8, 12);
scalar32_entry!(scalar32_12x8, 12, 8);
scalar32_entry!(scalar32_8x8, 8, 8);
scalar32_entry!(scalar32_8x6, 8, 6);
scalar32_entry!(scalar32_6x8, 6, 8);
scalar32_entry!(scalar32_16x4, 16, 4);
scalar32_entry!(scalar32_4x4, 4, 4);
scalar32_entry!(scalar32_2x2, 2, 2);
scalar32_entry!(scalar32_1x1, 1, 1);

/// True when the host can run the AVX2+FMA family.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn build_registry_f64() -> Vec<MicroKernelImpl<f64>> {
    let mut v: Vec<MicroKernelImpl<f64>> = Vec::new();
    let mk = MicroKernel::new;
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        let simd = |spec, func, name| MicroKernelImpl { spec, func, name, simd: true, prefetch: false };
        v.push(simd(mk(8, 6), avx2_8x6 as MicroKernelFn, "avx2_8x6"));
        v.push(MicroKernelImpl { spec: mk(8, 6), func: avx2_8x6_pf, name: "avx2_8x6_pf", simd: true, prefetch: true });
        v.push(simd(mk(12, 4), avx2_12x4, "avx2_12x4"));
        v.push(MicroKernelImpl { spec: mk(12, 4), func: avx2_12x4_pf, name: "avx2_12x4_pf", simd: true, prefetch: true });
        v.push(simd(mk(4, 12), avx2_4x12, "avx2_4x12"));
        v.push(simd(mk(8, 4), avx2_8x4, "avx2_8x4"));
        v.push(simd(mk(4, 8), avx2_4x8, "avx2_4x8"));
        v.push(simd(mk(4, 10), avx2_4x10, "avx2_4x10"));
        v.push(simd(mk(8, 2), avx2_8x2, "avx2_8x2"));
        v.push(simd(mk(4, 4), avx2_4x4, "avx2_4x4"));
    }
    let scalar = |spec, func, name| MicroKernelImpl { spec, func, name, simd: false, prefetch: false };
    v.push(scalar(mk(6, 8), scalar_6x8 as MicroKernelFn, "scalar_6x8"));
    v.push(scalar(mk(8, 6), scalar_8x6, "scalar_8x6"));
    v.push(scalar(mk(12, 4), scalar_12x4, "scalar_12x4"));
    v.push(scalar(mk(4, 12), scalar_4x12, "scalar_4x12"));
    v.push(scalar(mk(10, 4), scalar_10x4, "scalar_10x4"));
    v.push(scalar(mk(4, 10), scalar_4x10, "scalar_4x10"));
    v.push(scalar(mk(8, 8), scalar_8x8, "scalar_8x8"));
    v.push(scalar(mk(4, 4), scalar_4x4, "scalar_4x4"));
    v.push(scalar(mk(2, 2), scalar_2x2, "scalar_2x2"));
    v.push(scalar(mk(1, 1), scalar_1x1, "scalar_1x1"));
    v
}

fn build_registry_f32() -> Vec<MicroKernelImpl<f32>> {
    let mut v: Vec<MicroKernelImpl<f32>> = Vec::new();
    let mk = MicroKernel::new;
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        let simd = |spec, func, name| MicroKernelImpl { spec, func, name, simd: true, prefetch: false };
        v.push(simd(mk(16, 6), avx2s_16x6 as MicroKernelFnOf<f32>, "avx2s_16x6"));
        v.push(MicroKernelImpl {
            spec: mk(16, 6),
            func: avx2s_16x6_pf,
            name: "avx2s_16x6_pf",
            simd: true,
            prefetch: true,
        });
        v.push(simd(mk(8, 8), avx2s_8x8, "avx2s_8x8"));
        v.push(simd(mk(16, 4), avx2s_16x4, "avx2s_16x4"));
        v.push(simd(mk(8, 12), avx2s_8x12, "avx2s_8x12"));
        v.push(simd(mk(8, 6), avx2s_8x6, "avx2s_8x6"));
        v.push(simd(mk(8, 4), avx2s_8x4, "avx2s_8x4"));
    }
    let scalar = |spec, func, name| MicroKernelImpl { spec, func, name, simd: false, prefetch: false };
    v.push(scalar(mk(16, 6), scalar32_16x6 as MicroKernelFnOf<f32>, "scalar32_16x6"));
    v.push(scalar(mk(8, 12), scalar32_8x12, "scalar32_8x12"));
    v.push(scalar(mk(12, 8), scalar32_12x8, "scalar32_12x8"));
    v.push(scalar(mk(8, 8), scalar32_8x8, "scalar32_8x8"));
    v.push(scalar(mk(8, 6), scalar32_8x6, "scalar32_8x6"));
    v.push(scalar(mk(6, 8), scalar32_6x8, "scalar32_6x8"));
    v.push(scalar(mk(16, 4), scalar32_16x4, "scalar32_16x4"));
    v.push(scalar(mk(4, 4), scalar32_4x4, "scalar32_4x4"));
    v.push(scalar(mk(2, 2), scalar32_2x2, "scalar32_2x2"));
    v.push(scalar(mk(1, 1), scalar32_1x1, "scalar32_1x1"));
    v
}

/// The memoized f64 host registry (built — and feature-detected — once).
/// SIMD kernels are listed first so name-free lookups prefer them.
pub fn host_registry() -> &'static [MicroKernelImpl<f64>] {
    static REG: OnceLock<Vec<MicroKernelImpl<f64>>> = OnceLock::new();
    REG.get_or_init(build_registry_f64)
}

/// The memoized f32 host registry (built — and feature-detected — once).
pub fn host_registry_f32() -> &'static [MicroKernelImpl<f32>] {
    static REG: OnceLock<Vec<MicroKernelImpl<f32>>> = OnceLock::new();
    REG.get_or_init(build_registry_f32)
}

/// The registry of f64 micro-kernels runnable on this host (an owned
/// copy of the memoized table; entries are `Copy`, so this is a cheap
/// clone — feature detection is **not** re-run).
pub fn registry() -> Vec<MicroKernelImpl> {
    host_registry().to_vec()
}

/// The registry of f32 micro-kernels runnable on this host.
pub fn registry_f32() -> Vec<MicroKernelImpl<f32>> {
    host_registry_f32().to_vec()
}

/// Find an f64 kernel by name (memoized table lookup).
pub fn by_name(name: &str) -> Option<MicroKernelImpl> {
    host_registry().iter().find(|k| k.name == name).copied()
}

/// Find an f32 kernel by name (memoized table lookup).
pub fn by_name_f32(name: &str) -> Option<MicroKernelImpl<f32>> {
    host_registry_f32().iter().find(|k| k.name == name).copied()
}

/// Find the preferred (first-registered) f64 kernel for a shape.
pub fn for_shape(spec: MicroKernel) -> Option<MicroKernelImpl> {
    host_registry().iter().find(|k| k.spec == spec && !k.prefetch).copied()
}

/// Find the preferred (first-registered) f32 kernel for a shape.
pub fn for_shape_f32(spec: MicroKernel) -> Option<MicroKernelImpl<f32>> {
    host_registry_f32().iter().find(|k| k.spec == spec && !k.prefetch).copied()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::packing::{pack_a, pack_b, packed_a_len, packed_b_len};
    use crate::util::{Matrix, MatrixF64, Pcg64};

    /// Drive one micro-kernel over a random full-tile problem and compare
    /// with the naive product (generic over the element type).
    fn check_kernel_t<E: Elem>(imp: &MicroKernelImpl<E>, kc: usize, tol: f64) {
        let (mr, nr) = (imp.spec.mr, imp.spec.nr);
        let mut rng = Pcg64::seed(kc as u64 * 31 + mr as u64 * 7 + nr as u64);
        let a = Matrix::<E>::random(mr, kc, &mut rng);
        let b = Matrix::<E>::random(kc, nr, &mut rng);
        let mut c = Matrix::<E>::random(mr, nr, &mut rng);
        let mut expect = c.clone();
        crate::gemm::gemm_reference(E::ONE, a.view(), b.view(), E::ONE, &mut expect.view_mut());

        let mut abuf = vec![E::ZERO; packed_a_len(mr, kc, mr)];
        let mut bbuf = vec![E::ZERO; packed_b_len(kc, nr, nr)];
        pack_a(a.view(), &mut abuf, mr, E::ONE);
        pack_b(b.view(), &mut bbuf, nr);
        let ldc = c.ld();
        unsafe { (imp.func)(kc, abuf.as_ptr(), bbuf.as_ptr(), c.as_mut_ptr(), ldc) };
        assert!(
            c.max_abs_diff(&expect) < tol,
            "kernel {} kc={} diverges from reference",
            imp.name,
            kc
        );
    }

    fn check_kernel(imp: &MicroKernelImpl, kc: usize) {
        check_kernel_t::<f64>(imp, kc, 1e-11);
    }

    #[test]
    fn every_registered_kernel_matches_reference() {
        for imp in registry() {
            for kc in [1, 2, 7, 64, 129] {
                check_kernel(&imp, kc);
            }
        }
    }

    #[test]
    fn every_registered_f32_kernel_matches_reference() {
        for imp in registry_f32() {
            for kc in [1, 2, 7, 64, 129] {
                // f32: eps ~1.2e-7, |entries| < 1, error grows ~kc * eps.
                check_kernel_t::<f32>(&imp, kc, 1e-4);
            }
        }
    }

    #[test]
    fn kc_zero_is_identity() {
        for imp in registry().into_iter().take(3) {
            let (mr, nr) = (imp.spec.mr, imp.spec.nr);
            let mut c = MatrixF64::from_fn(mr, nr, |i, j| (i + 10 * j) as f64);
            let orig = c.clone();
            let abuf = vec![0.0; mr];
            let bbuf = vec![0.0; nr];
            let ldc = c.ld();
            unsafe { (imp.func)(0, abuf.as_ptr(), bbuf.as_ptr(), c.as_mut_ptr(), ldc) };
            assert_eq!(c, orig, "{} with kc=0 must not touch C", imp.name);
        }
    }

    #[test]
    fn registry_contains_paper_shapes() {
        let shapes: Vec<(usize, usize)> = registry().iter().map(|k| (k.spec.mr, k.spec.nr)).collect();
        for s in [(6, 8), (8, 6), (12, 4), (4, 12), (10, 4), (4, 10)] {
            assert!(shapes.contains(&s), "missing MK{}x{}", s.0, s.1);
        }
    }

    #[test]
    fn f32_registry_contains_wide_lane_shapes() {
        // The f32 family doubles the SIMD-natural mr: 16x6 and 8x8 are
        // the flagship shapes the ISSUE calls for.
        let shapes: Vec<(usize, usize)> =
            registry_f32().iter().map(|k| (k.spec.mr, k.spec.nr)).collect();
        for s in [(16, 6), (8, 8), (8, 12)] {
            assert!(shapes.contains(&s), "missing f32 MK{}x{}", s.0, s.1);
        }
        if avx2_available() {
            let k = for_shape_f32(MicroKernel::new(16, 6)).unwrap();
            assert!(k.simd, "SIMD kernel must be preferred for f32 16x6");
        }
    }

    #[test]
    fn lookup_by_name_and_shape() {
        assert!(by_name("scalar_6x8").is_some());
        assert!(by_name("does_not_exist").is_none());
        let k = for_shape(MicroKernel::new(8, 6)).unwrap();
        assert_eq!((k.spec.mr, k.spec.nr), (8, 6));
        if avx2_available() {
            assert!(k.simd, "SIMD kernel must be preferred for 8x6");
        }
        assert!(by_name_f32("scalar32_16x6").is_some());
    }

    #[test]
    fn registries_are_memoized() {
        // OnceLock memoization: repeated lookups must serve the same
        // static table (pointer-identical backing storage).
        let a = host_registry();
        let b = host_registry();
        assert!(std::ptr::eq(a.as_ptr(), b.as_ptr()), "f64 registry must be built once");
        let a32 = host_registry_f32();
        let b32 = host_registry_f32();
        assert!(std::ptr::eq(a32.as_ptr(), b32.as_ptr()), "f32 registry must be built once");
    }

    #[test]
    fn prefetch_variant_same_numerics() {
        if !avx2_available() {
            return;
        }
        let plain = by_name("avx2_8x6").unwrap();
        let pf = by_name("avx2_8x6_pf").unwrap();
        for kc in [3, 64] {
            check_kernel(&plain, kc);
            check_kernel(&pf, kc);
        }
        let plain32 = by_name_f32("avx2s_16x6").unwrap();
        let pf32 = by_name_f32("avx2s_16x6_pf").unwrap();
        for kc in [3, 64] {
            check_kernel_t::<f32>(&plain32, kc, 1e-4);
            check_kernel_t::<f32>(&pf32, kc, 1e-4);
        }
    }
}
