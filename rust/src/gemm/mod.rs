//! The native blocked GEMM engine: a faithful implementation of the
//! GotoBLAS2 five-loop algorithm of paper Figure 3, with
//!
//! - [`packing`] — the `Ac`/`Bc` packing routines (micro-panel layout),
//! - [`microkernel`] — a registry of micro-kernel implementations
//!   (portable const-generic scalar code and AVX2+FMA intrinsics),
//! - [`blocked`] — the five loops G1..G5 around packing + micro-kernel,
//! - [`parallel`] — loop G3/G4 multithreading (paper §2.2) broadcast on
//!   the persistent worker pool of [`crate::runtime::pool`], with
//!   cooperative packing (see the module docs for the barrier protocol)
//!   and the fused multi-GEMM batch driver (`gemm_batch_parallel`: N
//!   independent small GEMMs in one pool epoch, one team group each),
//! - [`api`] — the co-design entry point: per-call dynamic selection of
//!   micro-kernel and CCPs (the paper's contribution) with memoization,
//!   plus the static BLIS-like baseline mode.

pub mod abft;
pub mod api;
pub mod blocked;
pub mod microkernel;
pub mod packing;
pub mod parallel;

pub use abft::{AbftCounters, AbftPhase, AbftStats, VerifyPolicy};
pub use api::{
    ConfigCacheStats, ConfigMode, GemmBatchItem, GemmElem, GemmEngine, Lookahead, SchedPolicy,
    AUTO_PANEL_WORKERS,
};
pub use blocked::{gemm_blocked, Workspace};
pub use microkernel::{registry, registry_f32, MicroKernelImpl};
pub use parallel::{
    gemm_batch_parallel, gemm_fused_trailing, gemm_fused_trailing_ranges, gemm_parallel,
    BatchGemm, ParallelLoop, ThreadPlan,
};

/// Reference (naive triple-loop) GEMM: `C = alpha * A * B + beta * C`,
/// generic over the element type (accumulation happens in `E`, so the
/// f32 instantiation is a true f32 oracle). The correctness oracle for
/// everything in this module.
pub fn gemm_reference<E: crate::util::Elem>(
    alpha: E,
    a: crate::util::matrix::MatView<'_, E>,
    b: crate::util::matrix::MatView<'_, E>,
    beta: E,
    c: &mut crate::util::matrix::MatViewMut<'_, E>,
) {
    assert_eq!(a.cols, b.rows, "inner dimension mismatch");
    assert_eq!(c.rows, a.rows, "C row mismatch");
    assert_eq!(c.cols, b.cols, "C col mismatch");
    let (m, n, k) = (a.rows, b.cols, a.cols);
    for j in 0..n {
        for i in 0..m {
            let mut acc = E::ZERO;
            for p in 0..k {
                acc += a.at(i, p) * b.at(p, j);
            }
            let old = c.at(i, j);
            c.set(i, j, alpha * acc + beta * old);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{MatrixF64, Pcg64};

    #[test]
    fn reference_gemm_identity() {
        let mut rng = Pcg64::seed(11);
        let a = MatrixF64::random(5, 5, &mut rng);
        let i5 = MatrixF64::identity(5);
        let mut c = MatrixF64::zeros(5, 5);
        gemm_reference(1.0, a.view(), i5.view(), 0.0, &mut c.view_mut());
        assert!(c.max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn reference_gemm_alpha_beta() {
        let a = MatrixF64::from_row_major(2, 2, &[1., 2., 3., 4.]);
        let b = MatrixF64::from_row_major(2, 2, &[5., 6., 7., 8.]);
        let mut c = MatrixF64::from_row_major(2, 2, &[1., 1., 1., 1.]);
        // C = 2*A*B + 3*C
        gemm_reference(2.0, a.view(), b.view(), 3.0, &mut c.view_mut());
        // A*B = [[19,22],[43,50]]
        let expect = MatrixF64::from_row_major(2, 2, &[41., 47., 89., 103.]);
        assert!(c.max_abs_diff(&expect) < 1e-12);
    }
}
