//! Per-kind request metrics: latency histograms and flop throughput,
//! plus the shared GEMM pool's idle accounting (leader drain-wait,
//! between-job parked time, and the lookahead pipeline's per-phase split
//! — panel-team idle vs update-team idle vs queue-empty stalls) so
//! lookahead gains are observable in the server, not just in offline
//! benches — the batch scheduler's coalescing counters
//! ([`BatchMetrics`]: batch-size histogram, coalesced-vs-solo dispatch
//! counts, per-request admission-queue wait) — the mixed-precision
//! path's per-precision telemetry ([`RefineMetrics`]: refinement
//! iteration counts, f32-factor vs f64-refine seconds, fallbacks) — and
//! the failure-path accounting ([`FaultMetrics`]: rejected inputs,
//! expired deadlines, admission retries/rejections, worker panics and
//! the degraded-mode request count), so an operator can see a server
//! absorbing faults instead of silently retrying — and the
//! measurement-calibration counters ([`CalibrationMetrics`]: profile
//! observations, blended scores, explorations, config/team-size memo
//! hit rates), so calibrated selection is observable alongside the
//! analytic baseline.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::BTreeMap;

use crate::runtime::pool::PoolStats;
use crate::util::stats::{Accumulator, LatencyHistogram};

/// Counters of the mixed-precision solve path (`MixedSolve` requests):
/// how many solves ran, how hard the f64 refinement had to work, how the
/// time split between the f32 factorization and the f64 refinement, and
/// how often the clean f64 fallback fired.
#[derive(Clone, Debug)]
pub struct RefineMetrics {
    /// Mixed-precision solves served.
    pub solves: u64,
    /// Solves that fell back to the plain f64 path (ill-conditioned or
    /// f32-singular systems).
    pub fallbacks: u64,
    /// Refinement iterations per solve.
    pub iterations: Accumulator,
    /// Seconds spent factoring in f32, per solve.
    pub f32_factor_s: Accumulator,
    /// Seconds spent in the f64 residual/correction loop, per solve.
    pub refine_s: Accumulator,
}

impl Default for RefineMetrics {
    /// `Accumulator::new()` (not an all-zero accumulator) so `min`
    /// carries the +inf sentinel until the first solve is recorded.
    fn default() -> Self {
        Self {
            solves: 0,
            fallbacks: 0,
            iterations: Accumulator::new(),
            f32_factor_s: Accumulator::new(),
            refine_s: Accumulator::new(),
        }
    }
}

impl RefineMetrics {
    /// Record one mixed-precision solve.
    pub fn record(&mut self, iterations: usize, fell_back: bool, f32_factor_s: f64, refine_s: f64) {
        self.solves += 1;
        if fell_back {
            self.fallbacks += 1;
        }
        self.iterations.add(iterations as f64);
        self.f32_factor_s.add(f32_factor_s);
        self.refine_s.add(refine_s);
    }

    pub fn merge(&mut self, other: &RefineMetrics) {
        self.solves += other.solves;
        self.fallbacks += other.fallbacks;
        self.iterations.merge(&other.iterations);
        self.f32_factor_s.merge(&other.f32_factor_s);
        self.refine_s.merge(&other.refine_s);
    }
}

/// Counters of the server's batched-GEMM admission queue (see
/// `coordinator::server`): how often small requests actually coalesced,
/// how big the fused dispatches were, and how long requests waited in
/// the queue for companions.
#[derive(Clone, Debug)]
pub struct BatchMetrics {
    /// Fused dispatches holding two or more requests.
    pub batches: u64,
    /// Requests served inside those fused dispatches.
    pub coalesced_requests: u64,
    /// Single-request dispatches (a bucket's wait expired alone).
    pub solo: u64,
    /// Dispatch-size histogram: bucket `i` counts dispatches of size
    /// `i + 1`; the last bucket absorbs everything larger.
    pub size_hist: [u64; Self::HIST_BUCKETS],
    /// Per-request admission-queue wait (enqueue → dispatch) in
    /// nanoseconds.
    pub queue_wait_ns: Accumulator,
}

impl Default for BatchMetrics {
    /// `Accumulator::new()` (not the derived all-zero accumulator) so
    /// `queue_wait_ns.min` carries the +inf sentinel until the first
    /// real wait is recorded.
    fn default() -> Self {
        Self {
            batches: 0,
            coalesced_requests: 0,
            solo: 0,
            size_hist: [0; Self::HIST_BUCKETS],
            queue_wait_ns: Accumulator::new(),
        }
    }
}

impl BatchMetrics {
    pub const HIST_BUCKETS: usize = 16;

    /// Record one dispatch of `size` requests with the given per-request
    /// queue waits.
    pub fn record_dispatch(&mut self, size: usize, waits_ns: &[u64]) {
        debug_assert_eq!(size, waits_ns.len());
        if size == 0 {
            return;
        }
        if size >= 2 {
            self.batches += 1;
            self.coalesced_requests += size as u64;
        } else {
            self.solo += 1;
        }
        self.size_hist[(size - 1).min(Self::HIST_BUCKETS - 1)] += 1;
        for &w in waits_ns {
            self.queue_wait_ns.add(w as f64);
        }
    }

    /// Requests that went through the batcher (coalesced or solo).
    pub fn total_requests(&self) -> u64 {
        self.coalesced_requests + self.solo
    }

    /// Mean requests per dispatch (0 when nothing was dispatched).
    pub fn mean_batch_size(&self) -> f64 {
        let dispatches = self.batches + self.solo;
        if dispatches == 0 {
            0.0
        } else {
            self.total_requests() as f64 / dispatches as f64
        }
    }

    pub fn merge(&mut self, other: &BatchMetrics) {
        self.batches += other.batches;
        self.coalesced_requests += other.coalesced_requests;
        self.solo += other.solo;
        for (mine, theirs) in self.size_hist.iter_mut().zip(other.size_hist) {
            *mine += theirs;
        }
        self.queue_wait_ns.merge(&other.queue_wait_ns);
    }
}

/// Counters of the server's failure paths: how many requests were
/// rejected, retried, expired, or served degraded, and how many worker
/// threads panicked or were lost. All-zero on a healthy server — the
/// summary omits the `resilience:` line entirely in that case, so the
/// happy-path output is unchanged.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultMetrics {
    /// Requests rejected at admission by [`DlaRequest::validate`]
    /// (NaN/Inf operands, shape mismatches, bad block sizes).
    ///
    /// [`DlaRequest::validate`]: crate::coordinator::requests::DlaRequest::validate
    pub invalid_inputs: u64,
    /// Requests that expired — at the caller (reply not ready in time)
    /// or in the queue (deadline already past at dequeue).
    pub timeouts: u64,
    /// Submissions rejected with `QueueFull` after exhausting retries.
    pub queue_full_rejections: u64,
    /// Individual backoff-retry attempts spent at admission (counts
    /// every re-`try_send`, including ones that eventually succeeded).
    pub retries: u64,
    /// Requests whose handling panicked in a server worker (isolated by
    /// `catch_unwind`, answered with `DlaError::Internal`).
    pub worker_panics: u64,
    /// Requests served by the degraded serial fallback path after a
    /// pool poisoning (bitwise identical results, reduced throughput).
    pub degraded_requests: u64,
    /// Worker threads that terminated abnormally (observed at shutdown
    /// or via a disconnected channel).
    pub workers_lost: u64,
    /// Requests dropped in the admission queue because their deadline
    /// had already expired when a worker dequeued them.
    pub expired_in_queue: u64,
    /// Degraded-window slots still unconsumed at shutdown (the window
    /// was armed by a panic but the remaining requests never arrived).
    /// Zero on a server that never degraded or fully drained its window.
    pub degraded_remaining: u64,
}

impl FaultMetrics {
    /// True when every counter is zero (healthy server).
    pub fn is_clean(&self) -> bool {
        *self == FaultMetrics::default()
    }

    pub fn merge(&mut self, other: &FaultMetrics) {
        self.invalid_inputs += other.invalid_inputs;
        self.timeouts += other.timeouts;
        self.queue_full_rejections += other.queue_full_rejections;
        self.retries += other.retries;
        self.worker_panics += other.worker_panics;
        self.degraded_requests += other.degraded_requests;
        self.workers_lost += other.workers_lost;
        self.expired_in_queue += other.expired_in_queue;
        self.degraded_remaining += other.degraded_remaining;
    }
}

/// Per-tier QoS accounting: how many requests each [`Priority`] tier
/// submitted and how every one of them was resolved. Arrays are indexed
/// by `Priority::index()` (0 = Interactive, 1 = Batch, 2 = Background).
/// The no-silent-drops invariant is [`QosMetrics::reconciles`]: per
/// tier, `submitted == completed + failed + shed + rejected + cancelled`.
///
/// [`Priority`]: crate::coordinator::qos::Priority
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QosMetrics {
    /// Validated requests that entered admission, per tier.
    pub submitted: [u64; 3],
    /// Requests answered `Ok`, per tier.
    pub completed: [u64; 3],
    /// Requests answered with a server-side error (panic, breakdown,
    /// queue-expired deadline, ...), per tier.
    pub failed: [u64; 3],
    /// Requests shed at admission by the overload detector
    /// (`DlaError::Overloaded`), per tier.
    pub shed: [u64; 3],
    /// Requests rejected at admission after the tier's retry budget
    /// (`QueueFull`), on deadline expiry during backoff (`Timeout`), or
    /// against a closed queue (`WorkerLost`), per tier.
    pub rejected: [u64; 3],
    /// Requests cancelled through their `JobHandle` while still queued,
    /// per tier.
    pub cancelled: [u64; 3],
}

impl QosMetrics {
    /// True once any tier saw traffic (gates the summary line).
    pub fn any(&self) -> bool {
        self.submitted.iter().any(|&n| n > 0)
    }

    /// Total submissions across all tiers.
    pub fn total_submitted(&self) -> u64 {
        self.submitted.iter().sum()
    }

    /// The no-silent-drops invariant: every submitted request was
    /// resolved exactly one way.
    pub fn reconciles(&self) -> bool {
        (0..3).all(|i| {
            self.submitted[i]
                == self.completed[i]
                    + self.failed[i]
                    + self.shed[i]
                    + self.rejected[i]
                    + self.cancelled[i]
        })
    }

    pub fn merge(&mut self, other: &QosMetrics) {
        for i in 0..3 {
            self.submitted[i] += other.submitted[i];
            self.completed[i] += other.completed[i];
            self.failed[i] += other.failed[i];
            self.shed[i] += other.shed[i];
            self.rejected[i] += other.rejected[i];
            self.cancelled[i] += other.cancelled[i];
        }
    }
}

/// Counters of the ABFT verified-compute path (the serving-side view of
/// [`crate::gemm::AbftStats`]): how much work ran checksum-verified,
/// how many mismatches the checksums caught, how many the one-shot
/// recompute repaired, and what the verification cost. All-zero on a
/// server running with `VerifyPolicy::Off` — the summary omits the
/// `abft:` line entirely in that case.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AbftMetrics {
    /// Requests (GEMM dispatches / fused factorization jobs) that ran
    /// with checksums armed.
    pub verified_epochs: u64,
    /// Macro-blocks and factorization panels whose checksums verified
    /// clean.
    pub verified_blocks: u64,
    /// Checksum mismatches detected.
    pub detected: u64,
    /// Mismatches repaired by the one-shot recompute (`Correct` mode).
    pub corrected: u64,
    /// Mismatches that survived the recompute, plus every `Detect`-mode
    /// hit (detect never repairs).
    pub uncorrectable: u64,
    /// Nanoseconds spent computing and comparing checksums.
    pub overhead_ns: u64,
}

impl AbftMetrics {
    /// True once any verified work (or any detection) happened — gates
    /// the summary line.
    pub fn any(&self) -> bool {
        self.verified_epochs > 0 || self.verified_blocks > 0 || self.detected > 0
    }

    pub fn merge(&mut self, other: &AbftMetrics) {
        self.verified_epochs += other.verified_epochs;
        self.verified_blocks += other.verified_blocks;
        self.detected += other.detected;
        self.corrected += other.corrected;
        self.uncorrectable += other.uncorrectable;
        self.overhead_ns += other.overhead_ns;
    }
}

impl From<crate::gemm::AbftCounters> for AbftMetrics {
    fn from(c: crate::gemm::AbftCounters) -> Self {
        Self {
            verified_epochs: c.verified_epochs,
            verified_blocks: c.verified_blocks,
            detected: c.detected,
            corrected: c.corrected,
            uncorrectable: c.uncorrectable,
            overhead_ns: c.overhead_ns,
        }
    }
}

/// Counters of the measurement-calibrated selection layer (see
/// `crate::model::profile`): whether calibration is armed, how many
/// timings the shared [`PerfProfile`] absorbed, how often the blended
/// scorer actually consulted it, how many epsilon-exploration detours
/// fired, plus the engine-side config/team-size memo hit rates the
/// profile's generation key governs. All-zero-and-disabled on a server
/// running without `DLA_CALIBRATE` — the summary omits the
/// `calibration:` line entirely in that case, so the default output is
/// byte-identical.
///
/// [`PerfProfile`]: crate::model::PerfProfile
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CalibrationMetrics {
    /// True when a [`PerfProfile`](crate::model::PerfProfile) is
    /// attached to the engine (calibration armed).
    pub enabled: bool,
    /// Timed epochs recorded into the measurement store.
    pub observations: u64,
    /// Epsilon-exploration selections (runner-up configs tried).
    pub explorations: u64,
    /// Selections that blended a measured mean into the analytic score.
    pub blended: u64,
    /// Distinct (shape-bucket, dtype, config, width) keys in the store.
    pub store_entries: u64,
    /// GEMM config-cache memo hits.
    pub config_hits: u64,
    /// GEMM config-cache memo misses (full selection runs).
    pub config_misses: u64,
    /// Panel-team-size memo hits.
    pub team_hits: u64,
    /// Panel-team-size memo misses (model evaluations).
    pub team_misses: u64,
}

impl CalibrationMetrics {
    /// True once calibration is armed or any measurement landed — gates
    /// the summary line (memo counters alone don't; they predate this
    /// family and the healthy default output must stay byte-identical).
    pub fn any(&self) -> bool {
        self.enabled || self.observations > 0
    }

    pub fn merge(&mut self, other: &CalibrationMetrics) {
        self.enabled |= other.enabled;
        // Workers own disjoint engines, so memo counters sum...
        self.config_hits += other.config_hits;
        self.config_misses += other.config_misses;
        self.team_hits += other.team_hits;
        self.team_misses += other.team_misses;
        // ...but share one profile store, so every snapshot observes the
        // same monotone counters: keep the largest.
        self.observations = self.observations.max(other.observations);
        self.explorations = self.explorations.max(other.explorations);
        self.blended = self.blended.max(other.blended);
        self.store_entries = self.store_entries.max(other.store_entries);
    }
}

/// Metrics for one request kind.
#[derive(Default)]
pub struct KindMetrics {
    pub latency: LatencyHistogram,
    pub flops: Accumulator,
}

/// Coordinator-wide metrics.
#[derive(Default)]
pub struct Metrics {
    kinds: BTreeMap<String, KindMetrics>,
    /// Latest snapshot of the engine's worker-pool idle accounting
    /// (cumulative since pool construction). `None` for sequential
    /// engines.
    pool: Option<PoolStats>,
    /// Batched-dispatch accounting (all-zero on servers without
    /// batching).
    batch: BatchMetrics,
    /// Mixed-precision solve accounting (all-zero until a `MixedSolve`
    /// request is served).
    refine: RefineMetrics,
    /// Failure-path accounting (all-zero on a healthy server).
    faults: FaultMetrics,
    /// Per-tier QoS accounting (all-zero until the server folds its
    /// tier counters at shutdown).
    qos: QosMetrics,
    /// ABFT verified-compute accounting (all-zero under
    /// `VerifyPolicy::Off`).
    abft: AbftMetrics,
    /// Measurement-calibration accounting (disabled and all-zero
    /// without `DLA_CALIBRATE`; memo counters populate regardless).
    calibration: CalibrationMetrics,
    /// Admission-queue wait histogram (microsecond log2 buckets) — the
    /// percentile-capable companion of `batch.queue_wait_ns`.
    queue_wait: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, kind: &str, seconds: f64, flops: f64) {
        let km = self.kinds.entry(kind.to_string()).or_default();
        km.latency.record_secs(seconds);
        km.flops.add(flops);
    }

    pub fn count(&self, kind: &str) -> u64 {
        self.kinds.get(kind).map(|k| k.latency.count()).unwrap_or(0)
    }

    pub fn total_count(&self) -> u64 {
        self.kinds.values().map(|k| k.latency.count()).sum()
    }

    /// Mean GFLOPS of a kind (total flops / total time).
    pub fn mean_gflops(&self, kind: &str) -> f64 {
        match self.kinds.get(kind) {
            None => 0.0,
            Some(k) => {
                let total_s = k.latency.mean_us() * 1e-6 * k.latency.count() as f64;
                if total_s == 0.0 {
                    0.0
                } else {
                    k.flops.sum / total_s / 1e9
                }
            }
        }
    }

    /// Record the latest pool idle snapshot (counters are cumulative, so
    /// each call simply replaces the previous snapshot).
    pub fn set_pool_stats(&mut self, stats: PoolStats) {
        self.pool = Some(stats);
    }

    /// The most recent worker-pool idle snapshot, if a pool is attached.
    pub fn pool_stats(&self) -> Option<PoolStats> {
        self.pool
    }

    /// Record one batched dispatch (see [`BatchMetrics::record_dispatch`]).
    pub fn record_batch_dispatch(&mut self, size: usize, waits_ns: &[u64]) {
        self.batch.record_dispatch(size, waits_ns);
        for &w in waits_ns {
            self.queue_wait.record_secs(w as f64 * 1e-9);
        }
    }

    /// Replace the ABFT snapshot (the engine's counters are cumulative,
    /// so each call supersedes the previous one).
    pub fn set_abft(&mut self, c: crate::gemm::AbftCounters) {
        self.abft = AbftMetrics::from(c);
    }

    /// The ABFT verified-compute counters.
    pub fn abft_stats(&self) -> &AbftMetrics {
        &self.abft
    }

    /// Replace the calibration snapshot (profile and memo counters are
    /// cumulative, so each call supersedes the previous one).
    pub fn set_calibration(&mut self, c: CalibrationMetrics) {
        self.calibration = c;
    }

    /// The measurement-calibration counters.
    pub fn calibration_stats(&self) -> &CalibrationMetrics {
        &self.calibration
    }

    /// The batch scheduler's coalescing counters.
    pub fn batch_stats(&self) -> &BatchMetrics {
        &self.batch
    }

    /// Record one mixed-precision solve (see [`RefineMetrics::record`]).
    pub fn record_refine(&mut self, iterations: usize, fell_back: bool, f32_s: f64, refine_s: f64) {
        self.refine.record(iterations, fell_back, f32_s, refine_s);
    }

    /// The mixed-precision path's per-precision counters.
    pub fn refine_stats(&self) -> &RefineMetrics {
        &self.refine
    }

    /// Mutable access to the failure-path counters (the server bumps
    /// these at the fault sites; there is no single `record` shape).
    pub fn faults_mut(&mut self) -> &mut FaultMetrics {
        &mut self.faults
    }

    /// The failure-path counters.
    pub fn fault_stats(&self) -> &FaultMetrics {
        &self.faults
    }

    /// Mutable access to the per-tier QoS counters (the server folds its
    /// shared `TierCounters` snapshot here at shutdown).
    pub fn qos_mut(&mut self) -> &mut QosMetrics {
        &mut self.qos
    }

    /// The per-tier QoS counters.
    pub fn qos_stats(&self) -> &QosMetrics {
        &self.qos
    }

    pub fn merge(&mut self, other: Metrics) {
        // Workers of one server share a single pool, so every snapshot
        // observes the same monotone counters: keep the latest (largest
        // job count).
        if let Some(op) = other.pool {
            let keep = match self.pool {
                None => true,
                Some(p) => p.jobs <= op.jobs,
            };
            if keep {
                self.pool = Some(op);
            }
        }
        self.batch.merge(&other.batch);
        self.refine.merge(&other.refine);
        self.faults.merge(&other.faults);
        self.qos.merge(&other.qos);
        // Workers own disjoint engines, so ABFT counters sum.
        self.abft.merge(&other.abft);
        // Memo counters sum (disjoint engines); profile-store counters
        // keep the max (one shared store observed repeatedly).
        self.calibration.merge(&other.calibration);
        for _ in 0..other.queue_wait.count() {
            self.queue_wait.record_secs(other.queue_wait.mean_us() * 1e-6);
        }
        for (kind, km) in other.kinds {
            let mine = self.kinds.entry(kind).or_default();
            mine.flops.merge(&km.flops);
            // Histogram merge: re-record aggregate mean/count is lossy;
            // keep it simple by folding counts through record_secs.
            // (Workers usually report disjoint kinds or are summarized
            // individually; see server::drain_metrics.)
            for _ in 0..km.latency.count() {
                mine.latency.record_secs(km.latency.mean_us() * 1e-6);
            }
            let _ = km;
        }
    }

    /// Render a summary table.
    pub fn summary(&self) -> String {
        let mut t = crate::util::table::Table::new(
            "coordinator metrics",
            &["kind", "count", "mean ms", "p99 ms", "max ms", "GFLOPS"],
        );
        for (kind, km) in &self.kinds {
            t.row(&[
                kind.clone(),
                km.latency.count().to_string(),
                format!("{:.3}", km.latency.mean_us() / 1e3),
                format!("{:.3}", km.latency.quantile_us(0.99) / 1e3),
                format!("{:.3}", km.latency.max_us() / 1e3),
                format!("{:.2}", self.mean_gflops(kind)),
            ]);
        }
        let mut out = t.render();
        if let Some(p) = self.pool {
            // Poison accounting only shows up once an epoch actually
            // panicked, so healthy-server output is byte-identical.
            let poison = if p.epochs_poisoned > 0 {
                format!(
                    ", {} epochs poisoned ({} recovered)",
                    p.epochs_poisoned, p.recoveries
                )
            } else {
                String::new()
            };
            out.push_str(&format!(
                "gemm pool: {} jobs, leader-wait {:.3} ms, idle {:.3} ms{}\n",
                p.jobs,
                p.leader_wait_ns as f64 / 1e6,
                p.idle_ns as f64 / 1e6,
                poison,
            ));
            // DAG scheduler accounting only shows up once a tile graph
            // actually ran, so lookahead-only output is byte-identical.
            if p.dag_tasks > 0 {
                out.push_str(&format!(
                    "dag scheduler: {} tasks, {} steals ({} failed probes), \
                     deque high-water {}\n",
                    p.dag_tasks, p.dag_steals, p.dag_steal_fails, p.dag_deque_high_water,
                ));
            }
            out.push_str(&format!(
                "lookahead phases: panel-idle {:.3} ms, update-idle {:.3} ms, \
                 queue-stall {:.3} ms (rank-ms)\n",
                p.panel_idle_ns as f64 / 1e6,
                p.update_idle_ns as f64 / 1e6,
                p.queue_stall_ns as f64 / 1e6,
            ));
        }
        if self.batch.total_requests() > 0 {
            out.push_str(&format!(
                "batching: {} fused dispatches ({} coalesced requests, mean size {:.2}), \
                 {} solo, queue-wait mean {:.1} us\n",
                self.batch.batches,
                self.batch.coalesced_requests,
                self.batch.mean_batch_size(),
                self.batch.solo,
                self.batch.queue_wait_ns.mean() / 1e3,
            ));
        }
        if self.refine.solves > 0 {
            out.push_str(&format!(
                "mixed precision: {} solves, mean {:.1} refine iters, {} fallbacks, \
                 f32-factor mean {:.3} ms, refine mean {:.3} ms\n",
                self.refine.solves,
                self.refine.iterations.mean(),
                self.refine.fallbacks,
                self.refine.f32_factor_s.mean() * 1e3,
                self.refine.refine_s.mean() * 1e3,
            ));
        }
        if !self.faults.is_clean() {
            let f = &self.faults;
            // The remaining-window gauge only shows up when a degraded
            // window was still armed at shutdown, so pre-existing
            // resilience output is byte-identical.
            let remaining = if f.degraded_remaining > 0 {
                format!(", {} degraded-window remaining", f.degraded_remaining)
            } else {
                String::new()
            };
            out.push_str(&format!(
                "resilience: {} invalid inputs, {} timeouts ({} expired in queue), \
                 {} queue-full rejections ({} retries), {} worker panics, \
                 {} degraded requests, {} workers lost{}\n",
                f.invalid_inputs,
                f.timeouts,
                f.expired_in_queue,
                f.queue_full_rejections,
                f.retries,
                f.worker_panics,
                f.degraded_requests,
                f.workers_lost,
                remaining,
            ));
        }
        if self.abft.any() {
            let a = &self.abft;
            out.push_str(&format!(
                "abft: {} verified epochs ({} blocks), {} detected, {} corrected, \
                 {} uncorrectable, checksum overhead {:.3} ms\n",
                a.verified_epochs,
                a.verified_blocks,
                a.detected,
                a.corrected,
                a.uncorrectable,
                a.overhead_ns as f64 / 1e6,
            ));
        }
        if self.calibration.any() {
            let c = &self.calibration;
            out.push_str(&format!(
                "calibration: {} observations ({} store entries), {} blended scores, \
                 {} explorations, config memo {}/{} hits, team memo {}/{} hits\n",
                c.observations,
                c.store_entries,
                c.blended,
                c.explorations,
                c.config_hits,
                c.config_hits + c.config_misses,
                c.team_hits,
                c.team_hits + c.team_misses,
            ));
        }
        if self.qos.any() {
            let q = &self.qos;
            for (i, label) in ["interactive", "batch", "background"].iter().enumerate() {
                if q.submitted[i] == 0 {
                    continue;
                }
                out.push_str(&format!(
                    "qos {}: {} submitted, {} completed, {} shed, {} rejected, \
                     {} failed, {} cancelled\n",
                    label,
                    q.submitted[i],
                    q.completed[i],
                    q.shed[i],
                    q.rejected[i],
                    q.failed[i],
                    q.cancelled[i],
                ));
            }
        }
        out
    }

    /// One JSON object holding every counter family — the
    /// machine-readable counterpart of [`Metrics::summary`], dumped at
    /// server shutdown when `DLA_METRICS_JSON=1`. All keys are always
    /// present (zeroed families included) so downstream parsers never
    /// need existence checks; `pool` is `null` for sequential engines.
    pub fn snapshot_json(&self) -> String {
        let kinds: Vec<String> = self
            .kinds
            .iter()
            .map(|(kind, km)| {
                format!(
                    "\"{}\":{{\"count\":{},\"mean_ms\":{:.3},\"p50_ms\":{:.3},\
                     \"p99_ms\":{:.3},\"max_ms\":{:.3},\"gflops\":{:.2}}}",
                    kind,
                    km.latency.count(),
                    km.latency.mean_us() / 1e3,
                    km.latency.quantile_us(0.5) / 1e3,
                    km.latency.quantile_us(0.99) / 1e3,
                    km.latency.max_us() / 1e3,
                    self.mean_gflops(kind),
                )
            })
            .collect();
        let qw = &self.queue_wait;
        let queue_wait = format!(
            "{{\"count\":{},\"mean_us\":{:.1},\"p50_us\":{:.1},\"p90_us\":{:.1},\
             \"p99_us\":{:.1},\"max_us\":{:.1}}}",
            qw.count(),
            qw.mean_us(),
            qw.quantile_us(0.5),
            qw.quantile_us(0.9),
            qw.quantile_us(0.99),
            qw.max_us(),
        );
        let pool = match self.pool {
            None => "null".to_string(),
            Some(p) => format!(
                "{{\"jobs\":{},\"leader_wait_ns\":{},\"idle_ns\":{},\"panel_idle_ns\":{},\
                 \"update_idle_ns\":{},\"queue_stall_ns\":{},\"epochs_poisoned\":{},\
                 \"recoveries\":{},\"dag_tasks\":{},\"dag_steals\":{},\"dag_steal_fails\":{},\
                 \"dag_deque_high_water\":{}}}",
                p.jobs,
                p.leader_wait_ns,
                p.idle_ns,
                p.panel_idle_ns,
                p.update_idle_ns,
                p.queue_stall_ns,
                p.epochs_poisoned,
                p.recoveries,
                p.dag_tasks,
                p.dag_steals,
                p.dag_steal_fails,
                p.dag_deque_high_water,
            ),
        };
        let b = &self.batch;
        let batch = format!(
            "{{\"batches\":{},\"coalesced_requests\":{},\"solo\":{},\"mean_size\":{:.2}}}",
            b.batches,
            b.coalesced_requests,
            b.solo,
            b.mean_batch_size(),
        );
        let q = &self.qos;
        let tiers: Vec<String> = ["interactive", "batch", "background"]
            .iter()
            .enumerate()
            .map(|(i, label)| {
                format!(
                    "\"{}\":{{\"submitted\":{},\"completed\":{},\"failed\":{},\"shed\":{},\
                     \"rejected\":{},\"cancelled\":{}}}",
                    label,
                    q.submitted[i],
                    q.completed[i],
                    q.failed[i],
                    q.shed[i],
                    q.rejected[i],
                    q.cancelled[i],
                )
            })
            .collect();
        let r = &self.refine;
        let refine = format!(
            "{{\"solves\":{},\"fallbacks\":{},\"mean_iterations\":{:.2},\
             \"f32_factor_ms_mean\":{:.3},\"refine_ms_mean\":{:.3}}}",
            r.solves,
            r.fallbacks,
            r.iterations.mean(),
            r.f32_factor_s.mean() * 1e3,
            r.refine_s.mean() * 1e3,
        );
        let f = &self.faults;
        let faults = format!(
            "{{\"invalid_inputs\":{},\"timeouts\":{},\"expired_in_queue\":{},\
             \"queue_full_rejections\":{},\"retries\":{},\"worker_panics\":{},\
             \"degraded_requests\":{},\"workers_lost\":{},\"degraded_remaining\":{}}}",
            f.invalid_inputs,
            f.timeouts,
            f.expired_in_queue,
            f.queue_full_rejections,
            f.retries,
            f.worker_panics,
            f.degraded_requests,
            f.workers_lost,
            f.degraded_remaining,
        );
        let a = &self.abft;
        let abft = format!(
            "{{\"verified_epochs\":{},\"verified_blocks\":{},\"detected\":{},\
             \"corrected\":{},\"uncorrectable\":{},\"overhead_ns\":{}}}",
            a.verified_epochs,
            a.verified_blocks,
            a.detected,
            a.corrected,
            a.uncorrectable,
            a.overhead_ns,
        );
        let c = &self.calibration;
        let calibration = format!(
            "{{\"enabled\":{},\"observations\":{},\"explorations\":{},\"blended\":{},\
             \"store_entries\":{},\"config_hits\":{},\"config_misses\":{},\
             \"team_hits\":{},\"team_misses\":{}}}",
            c.enabled,
            c.observations,
            c.explorations,
            c.blended,
            c.store_entries,
            c.config_hits,
            c.config_misses,
            c.team_hits,
            c.team_misses,
        );
        format!(
            "{{\"requests\":{{{}}},\"queue_wait\":{},\"pool\":{},\"batch\":{},\
             \"qos\":{{{}}},\"refine\":{},\"faults\":{},\"abft\":{},\"calibration\":{}}}",
            kinds.join(","),
            queue_wait,
            pool,
            batch,
            tiers.join(","),
            refine,
            faults,
            abft,
            calibration,
        )
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn fault_metrics_merge_and_summarize() {
        let mut a = Metrics::new();
        assert!(a.fault_stats().is_clean());
        assert!(!a.summary().contains("resilience:"), "no line on a healthy server");
        a.faults_mut().invalid_inputs += 2;
        a.faults_mut().timeouts += 1;
        a.faults_mut().retries += 5;
        let mut b = Metrics::new();
        b.faults_mut().timeouts += 3;
        b.faults_mut().worker_panics += 1;
        b.faults_mut().degraded_requests += 4;
        a.merge(b);
        let f = a.fault_stats();
        assert_eq!(f.invalid_inputs, 2);
        assert_eq!(f.timeouts, 4);
        assert_eq!(f.retries, 5);
        assert_eq!(f.worker_panics, 1);
        assert_eq!(f.degraded_requests, 4);
        assert!(!f.is_clean());
        let s = a.summary();
        assert!(s.contains("resilience: 2 invalid inputs"), "{s}");
        assert!(s.contains("4 timeouts"), "{s}");
        assert!(s.contains("4 degraded requests"), "{s}");
    }

    #[test]
    fn qos_metrics_reconcile_merge_and_summarize() {
        let mut a = Metrics::new();
        assert!(!a.qos_stats().any());
        assert!(!a.summary().contains("qos "), "no qos lines without tier traffic");
        let q = a.qos_mut();
        q.submitted = [5, 2, 4];
        q.completed = [4, 2, 0];
        q.failed = [1, 0, 0];
        q.shed = [0, 0, 3];
        q.rejected = [0, 0, 1];
        assert!(a.qos_stats().reconciles());
        assert_eq!(a.qos_stats().total_submitted(), 11);
        let mut b = Metrics::new();
        b.qos_mut().submitted = [1, 0, 0];
        b.qos_mut().cancelled = [1, 0, 0];
        a.merge(b);
        let q = a.qos_stats();
        assert!(q.reconciles());
        assert_eq!(q.submitted, [6, 2, 4]);
        assert_eq!(q.cancelled, [1, 0, 0]);
        let s = a.summary();
        assert!(s.contains("qos interactive: 6 submitted, 4 completed"), "{s}");
        assert!(s.contains("qos background: 4 submitted, 0 completed, 3 shed, 1 rejected"), "{s}");
        // A lopsided ledger fails to reconcile.
        a.qos_mut().completed[0] += 1;
        assert!(!a.qos_stats().reconciles());
    }

    #[test]
    fn degraded_remaining_gauge_surfaces_only_when_armed() {
        let mut m = Metrics::new();
        m.faults_mut().degraded_requests = 3;
        assert!(!m.summary().contains("degraded-window remaining"), "drained window: no gauge");
        m.faults_mut().degraded_remaining = 5;
        assert!(!m.fault_stats().is_clean());
        let s = m.summary();
        assert!(s.contains("3 degraded requests"), "{s}");
        assert!(s.contains("5 degraded-window remaining"), "{s}");
    }

    #[test]
    fn pool_poison_counters_surface_only_when_nonzero() {
        use crate::runtime::pool::PoolStats;
        let mut m = Metrics::new();
        m.set_pool_stats(PoolStats { jobs: 5, ..PoolStats::default() });
        assert!(!m.summary().contains("poisoned"), "healthy pool line is unchanged");
        m.set_pool_stats(PoolStats {
            jobs: 6,
            epochs_poisoned: 2,
            recoveries: 2,
            ..PoolStats::default()
        });
        let s = m.summary();
        assert!(s.contains("2 epochs poisoned (2 recovered)"), "{s}");
    }

    #[test]
    fn dag_scheduler_counters_surface_only_when_nonzero() {
        use crate::runtime::pool::PoolStats;
        let mut m = Metrics::new();
        m.set_pool_stats(PoolStats { jobs: 1, ..PoolStats::default() });
        assert!(!m.summary().contains("dag scheduler"), "lookahead-only summary unchanged");
        assert!(m.snapshot_json().contains("\"dag_tasks\":0"), "{}", m.snapshot_json());
        m.set_pool_stats(PoolStats {
            jobs: 2,
            dag_tasks: 12,
            dag_steals: 3,
            dag_steal_fails: 5,
            dag_deque_high_water: 4,
            ..PoolStats::default()
        });
        let s = m.summary();
        assert!(
            s.contains("dag scheduler: 12 tasks, 3 steals (5 failed probes), deque high-water 4"),
            "{s}"
        );
        let j = m.snapshot_json();
        for frag in [
            "\"dag_tasks\":12",
            "\"dag_steals\":3",
            "\"dag_steal_fails\":5",
            "\"dag_deque_high_water\":4",
        ] {
            assert!(j.contains(frag), "{j}");
        }
    }

    #[test]
    fn abft_metrics_merge_and_summarize() {
        use crate::gemm::AbftCounters;
        let mut a = Metrics::new();
        assert!(!a.abft_stats().any());
        assert!(!a.summary().contains("abft:"), "no line without verified traffic");
        a.set_abft(AbftCounters {
            verified_epochs: 3,
            verified_blocks: 12,
            detected: 1,
            corrected: 1,
            uncorrectable: 0,
            overhead_ns: 2_000_000,
        });
        let mut b = Metrics::new();
        b.set_abft(AbftCounters {
            verified_epochs: 1,
            verified_blocks: 4,
            overhead_ns: 500_000,
            ..AbftCounters::default()
        });
        a.merge(b);
        let m = a.abft_stats();
        assert_eq!((m.verified_epochs, m.verified_blocks), (4, 16));
        assert_eq!((m.detected, m.corrected, m.uncorrectable), (1, 1, 0));
        assert_eq!(m.overhead_ns, 2_500_000);
        let s = a.summary();
        assert!(s.contains("abft: 4 verified epochs (16 blocks), 1 detected, 1 corrected"), "{s}");
    }

    #[test]
    fn snapshot_json_holds_every_family_and_stays_one_object() {
        use crate::gemm::AbftCounters;
        use crate::runtime::pool::PoolStats;
        let mut m = Metrics::new();
        // Empty metrics still produce every key.
        let j = m.snapshot_json();
        for key in [
            "requests",
            "queue_wait",
            "pool",
            "batch",
            "qos",
            "refine",
            "faults",
            "abft",
            "calibration",
        ] {
            assert!(j.contains(&format!("\"{key}\":")), "missing {key} in {j}");
        }
        assert!(j.contains("\"pool\":null"), "{j}");
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(!j.contains('\n'), "one line, one object");
        // Populated metrics surface their numbers.
        m.record("gemm", 0.002, 4e6);
        m.record_batch_dispatch(2, &[1_000, 3_000]);
        m.set_pool_stats(PoolStats { jobs: 9, ..PoolStats::default() });
        m.set_abft(AbftCounters { verified_epochs: 2, detected: 1, ..AbftCounters::default() });
        m.faults_mut().timeouts = 7;
        m.qos_mut().submitted = [3, 0, 0];
        let j = m.snapshot_json();
        assert!(j.contains("\"gemm\":{\"count\":1"), "{j}");
        assert!(j.contains("\"jobs\":9"), "{j}");
        assert!(j.contains("\"verified_epochs\":2"), "{j}");
        assert!(j.contains("\"detected\":1"), "{j}");
        assert!(j.contains("\"timeouts\":7"), "{j}");
        assert!(j.contains("\"interactive\":{\"submitted\":3"), "{j}");
        assert!(j.contains("\"count\":2,\"mean_us\":2.0"), "queue-wait stats in {j}");
    }

    #[test]
    fn calibration_metrics_merge_and_summarize() {
        let mut a = Metrics::new();
        assert!(!a.calibration_stats().any());
        assert!(!a.summary().contains("calibration:"), "no line without calibration traffic");
        // Memo counters alone (uncalibrated engine) must not add a line.
        a.set_calibration(CalibrationMetrics {
            config_hits: 7,
            config_misses: 3,
            team_hits: 2,
            team_misses: 1,
            ..CalibrationMetrics::default()
        });
        assert!(!a.summary().contains("calibration:"), "memo counters alone stay silent");
        assert!(a.snapshot_json().contains("\"config_hits\":7"), "{}", a.snapshot_json());
        // Armed calibration surfaces the line even before observations.
        a.set_calibration(CalibrationMetrics {
            enabled: true,
            observations: 40,
            explorations: 2,
            blended: 12,
            store_entries: 5,
            config_hits: 7,
            config_misses: 3,
            team_hits: 2,
            team_misses: 1,
        });
        let s = a.summary();
        assert!(s.contains("calibration: 40 observations (5 store entries)"), "{s}");
        assert!(s.contains("config memo 7/10 hits"), "{s}");
        assert!(s.contains("team memo 2/3 hits"), "{s}");
        // Merge: memo counters sum (disjoint engines), shared-store
        // counters keep the max (one profile observed twice).
        let mut b = Metrics::new();
        b.set_calibration(CalibrationMetrics {
            enabled: true,
            observations: 55,
            explorations: 1,
            blended: 9,
            store_entries: 6,
            config_hits: 4,
            config_misses: 2,
            team_hits: 1,
            team_misses: 1,
        });
        a.merge(b);
        let c = a.calibration_stats();
        assert!(c.enabled);
        assert_eq!((c.config_hits, c.config_misses), (11, 5));
        assert_eq!((c.team_hits, c.team_misses), (3, 2));
        assert_eq!((c.observations, c.explorations), (55, 2));
        assert_eq!((c.blended, c.store_entries), (12, 6));
        let j = a.snapshot_json();
        assert!(j.contains("\"calibration\":{\"enabled\":true,\"observations\":55"), "{j}");
    }

    #[test]
    fn record_and_query() {
        let mut m = Metrics::new();
        m.record("gemm", 0.001, 2e6);
        m.record("gemm", 0.003, 2e6);
        m.record("lu", 0.1, 6e9);
        assert_eq!(m.count("gemm"), 2);
        assert_eq!(m.count("lu"), 1);
        assert_eq!(m.total_count(), 3);
        // 4e6 flops over 4 ms = 1 GFLOPS.
        assert!((m.mean_gflops("gemm") - 1.0).abs() < 0.01);
        let s = m.summary();
        assert!(s.contains("gemm") && s.contains("lu"));
    }

    #[test]
    fn merge_accumulates_counts() {
        let mut a = Metrics::new();
        a.record("gemm", 0.001, 1e6);
        let mut b = Metrics::new();
        b.record("gemm", 0.002, 1e6);
        b.record("lu", 0.01, 1e9);
        a.merge(b);
        assert_eq!(a.count("gemm"), 2);
        assert_eq!(a.count("lu"), 1);
    }

    #[test]
    fn unknown_kind_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.count("nope"), 0);
        assert_eq!(m.mean_gflops("nope"), 0.0);
    }

    #[test]
    fn batch_metrics_count_merge_and_summarize() {
        let mut a = Metrics::new();
        assert_eq!(a.batch_stats().total_requests(), 0);
        assert!(!a.summary().contains("batching:"), "no line without batched traffic");
        // One 3-wide fused dispatch and one solo.
        a.record_batch_dispatch(3, &[1_000, 2_000, 3_000]);
        a.record_batch_dispatch(1, &[10_000]);
        let b = a.batch_stats();
        assert_eq!((b.batches, b.coalesced_requests, b.solo), (1, 3, 1));
        assert_eq!(b.total_requests(), 4);
        assert_eq!(b.size_hist[2], 1);
        assert_eq!(b.size_hist[0], 1);
        assert!((b.mean_batch_size() - 2.0).abs() < 1e-12);
        assert_eq!(b.queue_wait_ns.count, 4);
        // Oversized dispatches land in the last histogram bucket.
        let mut big = Metrics::new();
        big.record_batch_dispatch(40, &[500; 40]);
        assert_eq!(big.batch_stats().size_hist[BatchMetrics::HIST_BUCKETS - 1], 1);
        // Merge accumulates every counter.
        a.merge(big);
        let b = a.batch_stats();
        assert_eq!((b.batches, b.coalesced_requests, b.solo), (2, 43, 1));
        assert_eq!(b.queue_wait_ns.count, 44);
        let s = a.summary();
        assert!(s.contains("batching: 2 fused dispatches"), "{s}");
        assert!(s.contains("1 solo"), "{s}");
    }

    #[test]
    fn refine_metrics_record_merge_and_summarize() {
        let mut a = Metrics::new();
        assert_eq!(a.refine_stats().solves, 0);
        assert!(!a.summary().contains("mixed precision:"), "no line without mixed traffic");
        a.record_refine(2, false, 0.010, 0.004);
        a.record_refine(5, true, 0.012, 0.020);
        let r = a.refine_stats();
        assert_eq!((r.solves, r.fallbacks), (2, 1));
        assert!((r.iterations.mean() - 3.5).abs() < 1e-12);
        assert_eq!(r.iterations.count, 2);
        let mut b = Metrics::new();
        b.record_refine(1, false, 0.001, 0.001);
        a.merge(b);
        let r = a.refine_stats();
        assert_eq!((r.solves, r.fallbacks), (3, 1));
        assert_eq!(r.iterations.count, 3);
        let s = a.summary();
        assert!(s.contains("mixed precision: 3 solves"), "{s}");
        assert!(s.contains("1 fallbacks"), "{s}");
    }

    #[test]
    fn pool_stats_surface_and_merge_latest() {
        use crate::runtime::pool::PoolStats;
        let mut a = Metrics::new();
        assert!(a.pool_stats().is_none());
        a.set_pool_stats(PoolStats {
            jobs: 3,
            leader_wait_ns: 1_000_000,
            idle_ns: 2_000_000,
            ..PoolStats::default()
        });
        let mut b = Metrics::new();
        b.set_pool_stats(PoolStats {
            jobs: 7,
            leader_wait_ns: 4_000_000,
            idle_ns: 9_000_000,
            panel_idle_ns: 500_000,
            update_idle_ns: 250_000,
            queue_stall_ns: 125_000,
            ..PoolStats::default()
        });
        a.merge(b);
        assert_eq!(a.pool_stats().unwrap().jobs, 7, "merge keeps the latest snapshot");
        // An older snapshot must not regress the kept one.
        let mut c = Metrics::new();
        c.set_pool_stats(PoolStats { jobs: 2, leader_wait_ns: 1, idle_ns: 1, ..PoolStats::default() });
        a.merge(c);
        assert_eq!(a.pool_stats().unwrap().jobs, 7);
        let s = a.summary();
        assert!(s.contains("gemm pool: 7 jobs"), "{s}");
        // The per-phase lookahead idle split is part of the summary.
        assert!(s.contains("panel-idle 0.500 ms"), "{s}");
        assert!(s.contains("update-idle 0.250 ms"), "{s}");
        assert!(s.contains("queue-stall 0.125 ms"), "{s}");
    }
}
