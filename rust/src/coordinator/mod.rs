//! Layer-3 coordinator: the serving layer that makes the paper's
//! co-design *operational*.
//!
//! A [`Coordinator`] owns
//!
//! - the native [`crate::gemm::GemmEngine`] (with its pooled workspaces —
//!   the paper's "sufficiently-large workspace buffers"),
//! - optionally a PJRT [`crate::runtime::Registry`] of AOT artifacts,
//! - per-request metrics,
//!
//! and dispatches incoming DLA requests (GEMM, LU, Cholesky), performing
//! the per-call dynamic selection of micro-kernel + CCPs that the paper
//! argues BLAS libraries should expose. [`server`] wraps it in a
//! worker-thread request loop; [`lu_driver`] is the PJRT-backed blocked
//! LU (the end-to-end example's hot path).
//!
//! # Two schedulers on one pool
//!
//! The server composes two request schedulers over one shared persistent
//! worker pool:
//!
//! - **Small GEMMs** go through the *batch scheduler* (see [`server`]'s
//!   module docs): an admission queue buckets them by shape, the
//!   [`crate::model::batchplan`] cost model decides when a bucket is
//!   worth dispatching and how to partition the team across its members,
//!   and a fused multi-GEMM pool job executes the whole bucket in one
//!   epoch — bitwise identical per request to a solo dispatch. Knobs:
//!   [`ServerConfig::with_batching`] / [`BatchPolicy`], environment
//!   `DLA_BATCH`, `DLA_BATCH_WAIT_US`; observability:
//!   [`metrics::BatchMetrics`].
//! - **Factorizations and large GEMMs** bypass the batcher and keep the
//!   lookahead-fused path (`Lookahead` policy, `DLA_LOOKAHEAD`), which
//!   already keeps the pool busy across panel/update phases.
//!
//! # Failure model
//!
//! The request path speaks typed errors end to end: [`Coordinator::handle`]
//! and the server reply with `Result<DlaResponse, DlaError>` —
//! admission-validated inputs ([`DlaRequest::validate`]), factorization
//! breakdown as [`DlaError::Singular`], caught panics as
//! [`DlaError::Internal`], deadlines/backpressure as
//! [`DlaError::Timeout`] / [`DlaError::QueueFull`], and checksum
//! mismatches the verified-compute mode could not repair as
//! [`DlaError::DataCorrupt`] ([`crate::gemm::VerifyPolicy`],
//! `DLA_VERIFY`). See the "Failure model" section of
//! `lapack/README.md` for the full taxonomy and the degradation ladder.

// The serving path must stay panic-free: every unwrap/expect below is
// either allow-listed with a justification or lives in test code.
#![deny(clippy::unwrap_used, clippy::expect_used)]

#[cfg(feature = "pjrt")]
pub mod lu_driver;
pub mod metrics;
pub mod qos;
pub mod requests;
pub mod server;

#[cfg(feature = "pjrt")]
pub use lu_driver::{lu_via_artifacts, LuArtifactResult};
pub use crate::model::batchplan::BatchPolicy;
pub use crate::util::DlaError;
pub use metrics::{
    AbftMetrics, BatchMetrics, CalibrationMetrics, FaultMetrics, Metrics, QosMetrics,
    RefineMetrics,
};
pub use qos::{OverloadLevel, Priority};
pub use requests::{DlaRequest, DlaResponse};
pub use server::{CoordinatorServer, JobHandle, ServerConfig};

use crate::arch::Arch;
use crate::gemm::{ConfigMode, GemmEngine};
use crate::lapack;
use crate::lapack::refine::RefineOptions;
use crate::util::{DlaError, MatrixF64, Stopwatch};

/// The coordinator: policy + engine + metrics.
pub struct Coordinator {
    pub engine: GemmEngine,
    pub metrics: Metrics,
}

impl Coordinator {
    pub fn new(arch: Arch, mode: ConfigMode) -> Self {
        Self { engine: GemmEngine::new(arch, mode), metrics: Metrics::new() }
    }

    /// Attach a shared persistent worker pool (see
    /// [`crate::runtime::pool::WorkerPool`]): the engine keeps the team —
    /// and its memoized config selections — alive across every request
    /// this coordinator serves.
    pub fn with_pool(mut self, pool: std::sync::Arc<crate::runtime::pool::WorkerPool>) -> Self {
        self.engine.set_shared_pool(pool);
        self
    }

    /// Pin the engine's lookahead policy (see [`crate::gemm::Lookahead`])
    /// for the blocked factorizations this coordinator serves.
    pub fn with_lookahead(mut self, la: crate::gemm::Lookahead) -> Self {
        self.engine.set_lookahead(la);
        self
    }

    /// Pin the engine's ABFT verification policy (see
    /// [`crate::gemm::VerifyPolicy`]): every GEMM and factorization this
    /// coordinator serves runs checksum-verified, and a detected
    /// mismatch surfaces as [`DlaError::DataCorrupt`] instead of a
    /// silently wrong result.
    pub fn with_verify(mut self, policy: crate::gemm::VerifyPolicy) -> Self {
        self.engine.set_verify(policy);
        self
    }

    /// Attach a (shared) measurement store (see
    /// [`crate::model::profile`]): the engine times its pool dispatches
    /// and blends the analytic selection priors with measured GFLOPS,
    /// so config, team-size and batch decisions refine toward measured
    /// truth as this coordinator serves traffic.
    pub fn with_calibration(
        mut self,
        profile: std::sync::Arc<crate::model::PerfProfile>,
    ) -> Self {
        self.engine.set_calibration(Some(profile));
        self
    }

    /// Refresh the metrics' snapshot of the engine pool's idle accounting
    /// (no-op for sequential engines), of the engine's ABFT counters, and
    /// of the calibration/memo-cache counters. Called after every request
    /// so the summary always reflects the latest counters.
    fn snapshot_pool_stats(&mut self) {
        if let Some(pool) = self.engine.pool() {
            self.metrics.set_pool_stats(pool.stats());
        }
        self.metrics.set_abft(self.engine.abft_stats().snapshot());
        let cfg = self.engine.config_cache_stats();
        let team = self.engine.team_size_cache_stats();
        let prof = self.engine.profile().map(|p| p.stats()).unwrap_or_default();
        self.metrics.set_calibration(metrics::CalibrationMetrics {
            enabled: self.engine.profile().is_some(),
            observations: prof.observations,
            explorations: prof.explorations,
            blended: prof.blended,
            store_entries: prof.entries,
            config_hits: cfg.hits,
            config_misses: cfg.misses,
            team_hits: team.hits,
            team_misses: team.misses,
        });
    }

    /// Hit/miss accounting of the engine's config-selection memo cache
    /// (one selector run per distinct request shape, lookups thereafter).
    pub fn config_cache_stats(&self) -> crate::gemm::ConfigCacheStats {
        self.engine.config_cache_stats()
    }

    /// Handle one request synchronously. Malformed operands are rejected
    /// up front with [`DlaError::InvalidInput`]; factorization breakdown
    /// comes back as [`DlaError::Singular`]; a checksum mismatch the
    /// verified path could not repair comes back as
    /// [`DlaError::DataCorrupt`] — never a panic, never a silently wrong
    /// matrix.
    pub fn handle(&mut self, req: DlaRequest) -> Result<DlaResponse, DlaError> {
        let resp = self.handle_inner(req);
        // An unrepaired checksum mismatch trumps whatever the drive
        // produced: the computed matrix is not trustworthy.
        if let Some(corrupt) = self.engine.take_abft_failure() {
            self.snapshot_pool_stats();
            return Err(corrupt);
        }
        resp
    }

    fn handle_inner(&mut self, req: DlaRequest) -> Result<DlaResponse, DlaError> {
        req.validate()?;
        let sw = Stopwatch::start();
        let resp = match req {
            DlaRequest::Gemm { alpha, a, b, beta, mut c } => {
                let flops = 2.0 * a.rows() as f64 * b.cols() as f64 * a.cols() as f64;
                self.engine.gemm(alpha, a.view(), b.view(), beta, &mut c.view_mut());
                let dt = sw.elapsed_secs();
                self.metrics.record("gemm", dt, flops);
                DlaResponse::Matrix {
                    result: c,
                    config: self.engine.last_config.map(|c| c.to_string()),
                    seconds: dt,
                }
            }
            DlaRequest::GemmF32 { alpha, a, b, beta, mut c } => {
                let flops = 2.0 * a.rows() as f64 * b.cols() as f64 * a.cols() as f64;
                self.engine.gemm_f32(alpha, a.view(), b.view(), beta, &mut c.view_mut());
                let dt = sw.elapsed_secs();
                self.metrics.record("gemm_f32", dt, flops);
                DlaResponse::MatrixF32 {
                    result: c,
                    config: self.engine.last_config.map(|c| c.to_string()),
                    seconds: dt,
                }
            }
            DlaRequest::LuFactor { a, block } => {
                let flops = lapack::lu::lu_flops(a.rows());
                let factors = lapack::lu_factor(&a, block, &mut self.engine)
                    .map_err(|col| DlaError::Singular { pivot: col })?;
                let dt = sw.elapsed_secs();
                self.metrics.record("lu", dt, flops);
                DlaResponse::Lu { factors, seconds: dt }
            }
            DlaRequest::MixedSolve { a, rhs, block } => {
                let flops = lapack::lu::lu_flops(a.rows());
                let opts = RefineOptions { block, ..Default::default() };
                let res = lapack::lu_solve_mixed(&a, &rhs, &opts, &mut self.engine)
                    .map_err(|col| DlaError::Singular { pivot: col })?;
                let dt = sw.elapsed_secs();
                self.metrics.record("mixed_lu", dt, flops);
                self.metrics.record_refine(
                    res.iterations,
                    res.fell_back,
                    res.f32_factor_seconds,
                    res.refine_seconds,
                );
                DlaResponse::MixedSolve {
                    x: res.x,
                    iterations: res.iterations,
                    fell_back: res.fell_back,
                    residual: res.residual,
                    seconds: dt,
                }
            }
            DlaRequest::Cholesky { a, block } => {
                let s = a.rows();
                let flops = (s * s * s) as f64 / 3.0;
                let mut m = a;
                // Not-SPD is the Cholesky flavor of factorization
                // breakdown: same typed variant, pivot = failing column.
                lapack::cholesky::cholesky_blocked(&mut m, block, &mut self.engine)
                    .map_err(|col| DlaError::Singular { pivot: col })?;
                let dt = sw.elapsed_secs();
                self.metrics.record("cholesky", dt, flops);
                DlaResponse::Matrix { result: m, config: None, seconds: dt }
            }
        };
        self.snapshot_pool_stats();
        Ok(resp)
    }

    /// Convenience: factor + solve in one call (the "real small workload"
    /// of the end-to-end example).
    pub fn solve(
        &mut self,
        a: &MatrixF64,
        rhs: &MatrixF64,
        block: usize,
    ) -> Result<MatrixF64, DlaError> {
        match self.handle(DlaRequest::LuFactor { a: a.clone(), block })? {
            DlaResponse::Lu { factors, .. } => Ok(factors.solve(rhs)),
            _ => Err(DlaError::Internal {
                reason: "LuFactor request answered with a non-Lu response".to_string(),
            }),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::util::Pcg64;

    #[test]
    fn coordinator_gemm_roundtrip() {
        let mut co = Coordinator::new(host_xeon(), ConfigMode::Refined);
        let mut rng = Pcg64::seed(1);
        let a = MatrixF64::random(40, 24, &mut rng);
        let b = MatrixF64::random(24, 32, &mut rng);
        let c = MatrixF64::zeros(40, 32);
        let resp = co
            .handle(DlaRequest::Gemm { alpha: 1.0, a: a.clone(), b: b.clone(), beta: 0.0, c })
            .unwrap();
        let DlaResponse::Matrix { result, config, .. } = resp else { panic!() };
        let mut expect = MatrixF64::zeros(40, 32);
        crate::gemm::gemm_reference(1.0, a.view(), b.view(), 0.0, &mut expect.view_mut());
        assert!(result.max_abs_diff(&expect) < 1e-11);
        assert!(config.is_some());
        assert_eq!(co.metrics.count("gemm"), 1);
    }

    #[test]
    fn coordinator_lu_and_solve() {
        let mut co = Coordinator::new(host_xeon(), ConfigMode::Refined);
        let mut rng = Pcg64::seed(2);
        let a = MatrixF64::random_diag_dominant(48, &mut rng);
        let x_true = MatrixF64::random(48, 2, &mut rng);
        let mut rhs = MatrixF64::zeros(48, 2);
        crate::gemm::gemm_reference(1.0, a.view(), x_true.view(), 0.0, &mut rhs.view_mut());
        let x = co.solve(&a, &rhs, 8).unwrap();
        assert!(x.max_abs_diff(&x_true) < 1e-8);
        assert_eq!(co.metrics.count("lu"), 1);
    }

    #[test]
    fn coordinator_rejects_singular() {
        let mut co = Coordinator::new(host_xeon(), ConfigMode::Refined);
        let a = MatrixF64::zeros(8, 8);
        let err = co.handle(DlaRequest::LuFactor { a, block: 4 }).unwrap_err();
        assert_eq!(err, DlaError::Singular { pivot: 0 }, "typed singularity, not a string");
    }

    #[test]
    fn coordinator_rejects_invalid_input_before_any_work() {
        let mut co = Coordinator::new(host_xeon(), ConfigMode::Refined);
        let mut a = MatrixF64::identity(8);
        a[(1, 1)] = f64::NAN;
        let err = co.handle(DlaRequest::LuFactor { a, block: 4 }).unwrap_err();
        assert!(matches!(err, DlaError::InvalidInput { .. }), "{err:?}");
        assert_eq!(co.metrics.count("lu"), 0, "rejected requests must not be recorded");
    }

    #[test]
    fn coordinator_mixed_solve_and_f32_gemm() {
        use crate::util::MatrixF32;
        let mut co = Coordinator::new(host_xeon(), ConfigMode::Refined);
        let mut rng = Pcg64::seed(5);
        // Mixed-precision solve: f64-level residual, refine metrics.
        let a = MatrixF64::random_diag_dominant(48, &mut rng);
        let x_true = MatrixF64::random(48, 1, &mut rng);
        let mut rhs = MatrixF64::zeros(48, 1);
        crate::gemm::gemm_reference(1.0, a.view(), x_true.view(), 0.0, &mut rhs.view_mut());
        let resp = co.handle(DlaRequest::MixedSolve { a, rhs, block: 16 }).unwrap();
        let DlaResponse::MixedSolve { x, iterations, fell_back, residual, .. } = resp else {
            panic!()
        };
        assert!(!fell_back);
        assert!(iterations >= 1);
        assert!(residual <= 1e-10, "{residual}");
        assert!(x.max_abs_diff(&x_true) < 1e-8);
        assert_eq!(co.metrics.count("mixed_lu"), 1);
        assert_eq!(co.metrics.refine_stats().solves, 1);
        assert!(co.metrics.summary().contains("mixed precision:"));
        // f32 GEMM request on the same coordinator.
        let a = MatrixF32::random(20, 12, &mut rng);
        let b = MatrixF32::random(12, 16, &mut rng);
        let c = MatrixF32::zeros(20, 16);
        let resp = co
            .handle(DlaRequest::GemmF32 { alpha: 1.0, a: a.clone(), b: b.clone(), beta: 0.0, c })
            .unwrap();
        let DlaResponse::MatrixF32 { result, config, .. } = resp else { panic!() };
        let mut expect = MatrixF32::zeros(20, 16);
        crate::gemm::gemm_reference(1.0f32, a.view(), b.view(), 0.0f32, &mut expect.view_mut());
        assert!(result.max_abs_diff(&expect) < 1e-4);
        assert!(config.is_some());
        assert_eq!(co.metrics.count("gemm_f32"), 1);
    }

    #[test]
    fn coordinator_cholesky() {
        let mut co = Coordinator::new(host_xeon(), ConfigMode::Refined);
        let mut rng = Pcg64::seed(3);
        let m = MatrixF64::random(24, 24, &mut rng);
        let mt = m.transposed();
        let mut a = MatrixF64::zeros(24, 24);
        crate::gemm::gemm_reference(1.0, m.view(), mt.view(), 0.0, &mut a.view_mut());
        for i in 0..24 {
            a[(i, i)] += 24.0;
        }
        let resp = co.handle(DlaRequest::Cholesky { a: a.clone(), block: 8 }).unwrap();
        let DlaResponse::Matrix { result, .. } = resp else { panic!() };
        assert!(crate::lapack::cholesky::cholesky_residual(&a, &result) < 1e-11);
    }
}
