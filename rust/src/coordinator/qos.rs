//! QoS tiers, weighted-fair admission, and adaptive overload detection
//! for the coordinator server.
//!
//! This module owns the three serving-policy pieces the overload layer
//! is built from:
//!
//! - [`Priority`] — the three QoS tiers a request can be submitted at
//!   (`Interactive` > `Batch` > `Background`), each carrying its own
//!   dequeue weight and admission retry budget. The per-server default
//!   tier comes from `DLA_PRIORITY` (pinned via
//!   `ServerConfig::with_default_priority`), falling back to
//!   `Interactive` so un-annotated traffic keeps the pre-QoS behavior:
//!   never shed, full retry budget.
//! - [`QosQueue`] — the tiered admission queue that replaces a plain
//!   bounded channel: one FIFO per tier, one shared backpressure bound,
//!   and a credit-based weighted-fair dequeue ([`WeightedCredits`]) with
//!   a hard starvation bound — when every tier stays non-empty, each
//!   tier is served at least `weight` times per refill cycle of
//!   `sum(weights)` dispatches, so no tier can be starved forever by a
//!   hotter one.
//! - [`OverloadDetector`] — the queue-delay detector behind adaptive
//!   load shedding: it smooths the measured admission-queue wait and the
//!   per-request service cost (the larger of the `BatchPlanner` analytic
//!   estimate and the measured wall time — the analytic model is the
//!   floor, degraded service raises it) into two EWMAs and classifies
//!   their ratio into an [`OverloadLevel`]. The server sheds
//!   `Background` work at the first level and `Batch` work at the
//!   second with typed `DlaError::Overloaded`; `Interactive` is never
//!   shed. The severe level also arms *brownout*: a handler panic
//!   widens the degraded window by [`OverloadLevel::brownout_factor`]
//!   instead of letting the server collapse into a panic/retry spiral.
//!
//! Everything here is lock-light and allocation-free on the hot path:
//! the queue is one mutex + condvar (exactly what the channel it
//! replaces cost), the detector is two relaxed atomics.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use super::metrics::QosMetrics;

/// A request's QoS tier. Lower discriminant = higher priority.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive traffic: highest dequeue weight, the full
    /// admission retry budget, and **never shed** by the overload
    /// detector — the tier whose deadlines the shedding policy protects.
    Interactive = 0,
    /// Throughput traffic that still has a caller waiting: middle
    /// weight, middle retry budget, shed only at the severe overload
    /// level.
    Batch = 1,
    /// Best-effort work (bulk jobs, speculative prefetch, the `flood:N`
    /// drill): lowest weight, a minimal retry budget, first to be shed.
    Background = 2,
}

impl Priority {
    /// All tiers, highest priority first (index order).
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Batch, Priority::Background];

    /// Number of tiers (array dimension for per-tier counters).
    pub const COUNT: usize = 3;

    /// Dense index (0 = Interactive, 1 = Batch, 2 = Background).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Human label, also carried inside `DlaError::Overloaded`.
    pub fn label(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
            Priority::Background => "background",
        }
    }

    /// Weighted-fair dequeue weight: per refill cycle of
    /// `4 + 2 + 1 = 7` dispatches, Interactive is served 4 times, Batch
    /// 2, Background 1 (when every tier has work).
    pub fn weight(self) -> u32 {
        match self {
            Priority::Interactive => 4,
            Priority::Batch => 2,
            Priority::Background => 1,
        }
    }

    /// The per-tier admission retry budget: total `try_push` attempts
    /// (initial + retries) before a persistently full queue turns into
    /// `DlaError::QueueFull`. Interactive keeps the full pre-QoS budget;
    /// lower tiers give up sooner so their retries cannot amplify an
    /// overload.
    pub fn admission_attempts(self) -> u32 {
        match self {
            Priority::Interactive => 8,
            Priority::Batch => 4,
            Priority::Background => 2,
        }
    }

    /// Parse a tier name (`interactive` / `batch` / `background`,
    /// case-insensitive). `None` for anything else — a typo must fail
    /// toward the default tier, never toward silently shed traffic.
    pub fn parse(s: &str) -> Option<Priority> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("interactive") {
            Some(Priority::Interactive)
        } else if s.eq_ignore_ascii_case("batch") {
            Some(Priority::Batch)
        } else if s.eq_ignore_ascii_case("background") {
            Some(Priority::Background)
        } else {
            None
        }
    }

    /// The `DLA_PRIORITY` environment override for servers that did not
    /// pin a default tier; `None` when unset or unparseable.
    pub fn from_env() -> Option<Priority> {
        Priority::parse(std::env::var("DLA_PRIORITY").ok()?.as_str())
    }
}

impl Default for Priority {
    /// Un-annotated traffic is Interactive: never shed, full retry
    /// budget — exactly the pre-QoS serving behavior.
    fn default() -> Self {
        Priority::Interactive
    }
}

/// Credit-based weighted round-robin over the three tiers.
///
/// Each tier starts with `weight` credits. A pick scans tiers in
/// priority order and serves the first *eligible* (non-empty) tier that
/// still has credit, spending one; when every eligible tier is out of
/// credit, all credits refill to the weights and the scan repeats. The
/// starvation bound follows directly: a tier that stays eligible is
/// served at least `weight` times within every refill cycle, and a
/// cycle is at most `sum(weights)` picks long.
#[derive(Clone, Debug)]
pub struct WeightedCredits {
    weights: [u32; Priority::COUNT],
    credits: [u32; Priority::COUNT],
}

impl Default for WeightedCredits {
    fn default() -> Self {
        let weights =
            [Priority::Interactive.weight(), Priority::Batch.weight(), Priority::Background.weight()];
        Self { weights, credits: weights }
    }
}

impl WeightedCredits {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sum of all weights — the refill-cycle length, and therefore the
    /// starvation bound in dispatches.
    pub fn cycle_len(&self) -> u32 {
        self.weights.iter().sum()
    }

    /// Pick the tier index to serve among the `eligible` tiers, spending
    /// one credit (refilling every credit when the eligible tiers are
    /// all spent). `None` only when no tier is eligible.
    pub fn pick(&mut self, eligible: [bool; Priority::COUNT]) -> Option<usize> {
        if !eligible.iter().any(|&e| e) {
            return None;
        }
        loop {
            for i in 0..Priority::COUNT {
                if eligible[i] && self.credits[i] > 0 {
                    self.credits[i] -= 1;
                    return Some(i);
                }
            }
            // Every eligible tier is out of credit: start a new cycle.
            self.credits = self.weights;
        }
    }
}

/// Why a [`QosQueue::try_push`] handed the item back.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue is at its shared backpressure bound; the caller may
    /// retry (within its tier's budget) or reject.
    Full(T),
    /// The queue was closed (server shutting down); never retried.
    Closed(T),
}

struct QosState<T> {
    queues: [VecDeque<T>; Priority::COUNT],
    credits: WeightedCredits,
    pending: usize,
    closed: bool,
}

/// The tiered admission queue: one FIFO per [`Priority`], a single
/// shared backpressure bound across all tiers (so low-priority floods
/// cannot grow memory without bound), and a blocking weighted-fair
/// [`QosQueue::pop`]. Replaces the server's bounded `sync_channel` —
/// same cost shape (one mutex + condvar), tier-aware dequeue.
pub struct QosQueue<T> {
    max_pending: usize,
    state: Mutex<QosState<T>>,
    cv: Condvar,
}

impl<T> QosQueue<T> {
    /// A queue bounded at `max_pending` total entries across all tiers.
    pub fn new(max_pending: usize) -> Self {
        Self {
            max_pending: max_pending.max(1),
            state: Mutex::new(QosState {
                queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                credits: WeightedCredits::new(),
                pending: 0,
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue at a tier, or hand the item back when the queue is at its
    /// bound ([`PushError::Full`], retryable) or closed
    /// ([`PushError::Closed`], terminal).
    pub fn try_push(&self, tier: Priority, item: T) -> Result<(), PushError<T>> {
        {
            let mut st = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if st.closed {
                return Err(PushError::Closed(item));
            }
            if st.pending >= self.max_pending {
                return Err(PushError::Full(item));
            }
            st.pending += 1;
            st.queues[tier.index()].push_back(item);
        }
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking weighted-fair dequeue. Returns `None` only once the
    /// queue is closed **and** fully drained — every accepted entry is
    /// handed to a consumer before shutdown completes.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        loop {
            let eligible = [
                !st.queues[0].is_empty(),
                !st.queues[1].is_empty(),
                !st.queues[2].is_empty(),
            ];
            if eligible.iter().any(|&e| e) {
                if let Some(i) = st.credits.pick(eligible) {
                    if let Some(item) = st.queues[i].pop_front() {
                        st.pending -= 1;
                        return Some(item);
                    }
                }
                // Defensive: pick() disagreed with the emptiness probe
                // (impossible under this lock) — re-evaluate, never hang.
                continue;
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    /// Entries currently parked across all tiers.
    pub fn pending(&self) -> usize {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner).pending
    }

    /// Close the queue: pushes fail with [`PushError::Closed`], pops
    /// drain the remaining entries and then return `None`. Idempotent.
    pub fn close(&self) {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner).closed = true;
        self.cv.notify_all();
    }
}

/// The overload classification the detector reports, ordered by
/// severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OverloadLevel {
    /// Queue delay is commensurate with service cost: admit everything.
    Healthy = 0,
    /// Queue delay has outrun service cost: shed `Background`.
    SheddingBackground = 1,
    /// Severe overload: shed `Batch` too, and arm brownout — a handler
    /// panic in this state widens the degraded window by
    /// [`Self::brownout_factor`] instead of collapsing.
    SheddingBatch = 2,
}

impl OverloadLevel {
    /// How much a handler panic widens the degraded serial window at
    /// this level (brownout: under severe overload the server trades
    /// much more throughput for stability instead of oscillating between
    /// the pooled path and fresh panics).
    pub fn brownout_factor(self) -> u64 {
        match self {
            OverloadLevel::Healthy | OverloadLevel::SheddingBackground => 1,
            OverloadLevel::SheddingBatch => 4,
        }
    }
}

/// EWMA smoothing shift: `alpha = 1/8` (new = old - old/8 + sample/8),
/// seeded with the first sample so one genuinely long wait is visible
/// immediately.
const EWMA_SHIFT: u32 = 3;
/// Below this smoothed queue delay the server is Healthy regardless of
/// the ratio — microsecond-scale waits on microsecond-scale requests are
/// not overload.
const MIN_WAIT_US: u64 = 500;
/// Floor for the smoothed cost, so the ratio stays meaningful for
/// near-zero estimates (degenerate shapes).
const COST_FLOOR_US: u64 = 50;
/// Queue delay / service cost ratio at which Background is shed.
const SHED_BACKGROUND_RATIO: u64 = 4;
/// Ratio at which Batch is shed too and brownout arms.
const SHED_BATCH_RATIO: u64 = 12;

/// Queue-delay overload detector: two EWMAs (measured admission-queue
/// wait; per-request service cost = max(analytic estimate, measured
/// wall time)) and a ratio classifier. All updates are relaxed atomics —
/// the detector tolerates torn interleavings, it only has to be right on
/// average.
#[derive(Debug, Default)]
pub struct OverloadDetector {
    ewma_wait_us: AtomicU64,
    ewma_cost_us: AtomicU64,
}

fn ewma_update(cell: &AtomicU64, sample: u64) {
    let _ = cell.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |old| {
        Some(if old == 0 {
            sample
        } else {
            old - (old >> EWMA_SHIFT) + (sample >> EWMA_SHIFT)
        })
    });
}

impl OverloadDetector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request's measured admission-queue wait (submit →
    /// dequeue), in microseconds.
    pub fn observe_wait_us(&self, us: u64) {
        ewma_update(&self.ewma_wait_us, us);
    }

    /// Record one request's service cost in microseconds — the caller
    /// passes `max(analytic estimate, measured wall time)`: the
    /// `BatchPlanner` model is the floor, so a debug build or a degraded
    /// machine (measured ≫ model) raises the baseline instead of
    /// tripping the detector on model error.
    pub fn observe_cost_us(&self, us: u64) {
        ewma_update(&self.ewma_cost_us, us.max(1));
    }

    /// The smoothed queue delay, in microseconds (what
    /// `DlaError::Overloaded` carries).
    pub fn queue_delay_us(&self) -> u64 {
        self.ewma_wait_us.load(Ordering::Relaxed)
    }

    /// Classify the current wait/cost ratio.
    pub fn level(&self) -> OverloadLevel {
        let wait = self.ewma_wait_us.load(Ordering::Relaxed);
        if wait < MIN_WAIT_US {
            return OverloadLevel::Healthy;
        }
        let cost = self.ewma_cost_us.load(Ordering::Relaxed).max(COST_FLOOR_US);
        if wait >= cost.saturating_mul(SHED_BATCH_RATIO) {
            OverloadLevel::SheddingBatch
        } else if wait >= cost.saturating_mul(SHED_BACKGROUND_RATIO) {
            OverloadLevel::SheddingBackground
        } else {
            OverloadLevel::Healthy
        }
    }

    /// Does the current level shed this tier? `Interactive` is never
    /// shed — the whole point of shedding the others is to keep its
    /// deadlines safe.
    pub fn sheds(&self, tier: Priority) -> bool {
        match tier {
            Priority::Interactive => false,
            Priority::Batch => self.level() >= OverloadLevel::SheddingBatch,
            Priority::Background => self.level() >= OverloadLevel::SheddingBackground,
        }
    }
}

/// Per-tier submission/outcome counters, shared (`Arc`) between the
/// submit side, the workers, and the batcher; folded into
/// [`QosMetrics`] at shutdown. The accounting invariant (asserted by
/// `tests/qos.rs`): for every tier,
/// `submitted == completed + failed + shed + rejected + cancelled` —
/// no silent drops.
#[derive(Debug, Default)]
pub struct TierCounters {
    submitted: [AtomicU64; Priority::COUNT],
    completed: [AtomicU64; Priority::COUNT],
    failed: [AtomicU64; Priority::COUNT],
    shed: [AtomicU64; Priority::COUNT],
    rejected: [AtomicU64; Priority::COUNT],
    cancelled: [AtomicU64; Priority::COUNT],
}

impl TierCounters {
    pub fn new() -> Self {
        Self::default()
    }

    /// A validated request entered admission at this tier.
    pub fn add_submitted(&self, t: Priority) {
        self.submitted[t.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// A request was answered `Ok`.
    pub fn add_completed(&self, t: Priority) {
        self.completed[t.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// A request was answered with a server-side error (panic, breakdown,
    /// deadline expiry in the queue, ...).
    pub fn add_failed(&self, t: Priority) {
        self.failed[t.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// The overload detector shed the request at admission
    /// (`DlaError::Overloaded`).
    pub fn add_shed(&self, t: Priority) {
        self.shed[t.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Admission gave up (`QueueFull` after the tier's retry budget,
    /// deadline expiry during backoff, or a closed queue).
    pub fn add_rejected(&self, t: Priority) {
        self.rejected[t.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// The caller cancelled the job while it was still queued.
    pub fn add_cancelled(&self, t: Priority) {
        self.cancelled[t.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot into the plain metrics struct.
    pub fn snapshot(&self) -> QosMetrics {
        let load = |a: &[AtomicU64; Priority::COUNT]| {
            [a[0].load(Ordering::Relaxed), a[1].load(Ordering::Relaxed), a[2].load(Ordering::Relaxed)]
        };
        QosMetrics {
            submitted: load(&self.submitted),
            completed: load(&self.completed),
            failed: load(&self.failed),
            shed: load(&self.shed),
            rejected: load(&self.rejected),
            cancelled: load(&self.cancelled),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn priority_parse_and_env_default() {
        assert_eq!(Priority::parse("interactive"), Some(Priority::Interactive));
        assert_eq!(Priority::parse(" Batch "), Some(Priority::Batch));
        assert_eq!(Priority::parse("BACKGROUND"), Some(Priority::Background));
        assert_eq!(Priority::parse("realtime"), None, "typos fail toward the default tier");
        assert_eq!(Priority::default(), Priority::Interactive);
        assert!(Priority::Interactive < Priority::Batch);
        assert_eq!(Priority::ALL.map(Priority::index), [0, 1, 2]);
    }

    #[test]
    fn budgets_and_weights_are_tier_ordered() {
        assert!(Priority::Interactive.weight() > Priority::Batch.weight());
        assert!(Priority::Batch.weight() > Priority::Background.weight());
        assert!(
            Priority::Interactive.admission_attempts() > Priority::Batch.admission_attempts()
        );
        assert!(
            Priority::Batch.admission_attempts() > Priority::Background.admission_attempts()
        );
    }

    #[test]
    fn weighted_credits_follow_the_weights() {
        let mut c = WeightedCredits::new();
        let all = [true, true, true];
        let cycle = c.cycle_len() as usize;
        let picks: Vec<usize> = (0..cycle).map(|_| c.pick(all).unwrap()).collect();
        let count = |t: usize| picks.iter().filter(|&&p| p == t).count() as u32;
        assert_eq!(count(0), Priority::Interactive.weight());
        assert_eq!(count(1), Priority::Batch.weight());
        assert_eq!(count(2), Priority::Background.weight());
        // Only one tier eligible: it is always picked (credits refill).
        for _ in 0..20 {
            assert_eq!(c.pick([false, false, true]), Some(2));
        }
        assert_eq!(c.pick([false, false, false]), None);
    }

    #[test]
    fn queue_is_weighted_fair_and_starvation_bounded() {
        let q: QosQueue<usize> = QosQueue::new(64);
        for i in 0..12 {
            q.try_push(Priority::Interactive, i).ok().unwrap();
        }
        for i in 0..6 {
            q.try_push(Priority::Batch, 100 + i).ok().unwrap();
        }
        for i in 0..3 {
            q.try_push(Priority::Background, 200 + i).ok().unwrap();
        }
        q.close();
        let mut order = Vec::new();
        while let Some(v) = q.pop() {
            order.push(v);
        }
        assert_eq!(order.len(), 21, "close drains everything");
        // First refill cycle (7 pops): 4 interactive, 2 batch, 1
        // background — the weights, in priority-scan order.
        assert_eq!(&order[..7], &[0, 1, 2, 3, 100, 101, 200]);
        // Starvation bound: while background stays non-empty, the gap
        // between consecutive background pops is at most one refill
        // cycle.
        let bg: Vec<usize> =
            order.iter().enumerate().filter(|(_, &v)| v >= 200).map(|(i, _)| i).collect();
        assert_eq!(bg.len(), 3);
        for w in bg.windows(2) {
            assert!(w[1] - w[0] <= 7, "background starved: pops at {bg:?}");
        }
        // Per-tier FIFO order is preserved.
        let inter: Vec<usize> = order.iter().copied().filter(|&v| v < 100).collect();
        assert_eq!(inter, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn queue_bounds_and_close_semantics() {
        let q: QosQueue<u32> = QosQueue::new(2);
        assert!(q.try_push(Priority::Background, 1).is_ok());
        assert!(q.try_push(Priority::Interactive, 2).is_ok());
        match q.try_push(Priority::Interactive, 3) {
            Err(PushError::Full(v)) => assert_eq!(v, 3, "the bound hands the item back"),
            _ => panic!("third push must see Full"),
        }
        assert_eq!(q.pending(), 2);
        q.close();
        match q.try_push(Priority::Interactive, 4) {
            Err(PushError::Closed(v)) => assert_eq!(v, 4),
            _ => panic!("post-close push must see Closed"),
        }
        // Drain-then-None: accepted entries are never dropped.
        assert_eq!(q.pop(), Some(2), "interactive first");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn detector_levels_and_shedding_policy() {
        let d = OverloadDetector::new();
        assert_eq!(d.level(), OverloadLevel::Healthy);
        assert!(!d.sheds(Priority::Background), "cold detector sheds nothing");
        // Commensurate wait and cost: healthy even at millisecond scale.
        d.observe_wait_us(2_000);
        d.observe_cost_us(1_500);
        assert_eq!(d.level(), OverloadLevel::Healthy);
        // Waits outrun cost past the first ratio: Background shed.
        for _ in 0..40 {
            d.observe_wait_us(10_000);
        }
        assert_eq!(d.level(), OverloadLevel::SheddingBackground);
        assert!(d.sheds(Priority::Background));
        assert!(!d.sheds(Priority::Batch));
        assert!(!d.sheds(Priority::Interactive));
        // Far past the severe ratio: Batch shed too, brownout armed.
        for _ in 0..60 {
            d.observe_wait_us(60_000);
        }
        assert_eq!(d.level(), OverloadLevel::SheddingBatch);
        assert!(d.sheds(Priority::Batch));
        assert!(!d.sheds(Priority::Interactive), "interactive is never shed");
        assert!(d.queue_delay_us() > MIN_WAIT_US);
        // Recovery: waits fall back toward cost → healthy again.
        for _ in 0..120 {
            d.observe_wait_us(100);
        }
        assert_eq!(d.level(), OverloadLevel::Healthy);
    }

    #[test]
    fn sub_threshold_waits_never_shed() {
        let d = OverloadDetector::new();
        // Huge ratio but microsecond-scale waits: not overload.
        for _ in 0..50 {
            d.observe_wait_us(400);
            d.observe_cost_us(1);
        }
        assert_eq!(d.level(), OverloadLevel::Healthy);
    }

    #[test]
    fn brownout_factor_by_level() {
        assert_eq!(OverloadLevel::Healthy.brownout_factor(), 1);
        assert_eq!(OverloadLevel::SheddingBackground.brownout_factor(), 1);
        assert_eq!(OverloadLevel::SheddingBatch.brownout_factor(), 4);
    }

    #[test]
    fn tier_counters_snapshot_and_reconcile() {
        let c = TierCounters::new();
        for _ in 0..5 {
            c.add_submitted(Priority::Interactive);
        }
        c.add_completed(Priority::Interactive);
        c.add_completed(Priority::Interactive);
        c.add_failed(Priority::Interactive);
        c.add_cancelled(Priority::Interactive);
        c.add_rejected(Priority::Interactive);
        c.add_submitted(Priority::Background);
        c.add_shed(Priority::Background);
        let m = c.snapshot();
        assert!(m.reconciles(), "{m:?}");
        assert_eq!(m.submitted[0], 5);
        assert_eq!(m.shed[2], 1);
        assert_eq!(m.total_submitted(), 6);
    }
}
