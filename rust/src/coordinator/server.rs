//! A worker-thread request loop around the [`super::Coordinator`]:
//! requests flow through a bounded channel (backpressure), each worker
//! owns its engine (and thus its workspace pool and config-selection memo
//! cache), and per-worker metrics are merged at shutdown.
//!
//! With [`ServerConfig::with_gemm_threads`] the server provisions **one**
//! persistent GEMM worker pool at startup and shares it across every
//! request worker's engine: heavy requests get intra-request parallelism,
//! the team is spawned exactly once for the lifetime of the server (pool
//! `run`s from different workers serialize on the pool's leader lock, so
//! the machine is never oversubscribed), and no request ever pays thread
//! creation cost.
//!
//! # The batched request scheduler
//!
//! With batching enabled ([`ServerConfig::with_batching`], or the
//! `DLA_BATCH` / `DLA_BATCH_WAIT_US` environment knobs on un-pinned
//! servers), small GEMM requests no longer each run one whole pool
//! dispatch under the leader lock. Instead the request path becomes:
//!
//! 1. **Admission.** A worker pulls a request from the channel as usual,
//!    but routes it into the admission queue when the
//!    [`crate::model::batchplan`] cost model says a full-team dispatch
//!    would waste the machine (estimated single-core time below the
//!    policy threshold, or a G4 grain too small to feed the team). The
//!    queue **buckets by problem shape**; factorizations and large GEMMs
//!    bypass the batcher entirely and keep the existing (lookahead)
//!    path — the two schedulers compose on one shared pool. Parked
//!    entries are bounded by `queue_depth` (preserving the channel's
//!    backpressure); at the bound, requests are served solo.
//! 2. **Coalescing.** A dedicated batcher thread sleeps until a bucket
//!    is dispatchable: it reached `max_batch` entries, its oldest entry
//!    has waited `wait_us`, or the server is shutting down.
//! 3. **Fused dispatch.** The bucket is executed as one (or, above the
//!    team width, a few chunked) fused pool epoch(s) via
//!    [`crate::gemm::GemmEngine::gemm_batch`]: the team is partitioned
//!    across the batch members by the same cost model, every member
//!    keeps its own memoized per-shape configuration, and each result is
//!    **bitwise identical** to what a solo dispatch would have produced
//!    (asserted by `tests/batching.rs`).
//!
//! Per-batch observability (dispatch-size histogram, coalesced-vs-solo
//! counts, per-request queue wait) is recorded in
//! [`super::metrics::BatchMetrics`] and merged into the server metrics
//! at shutdown. A response served from a fused dispatch reports the
//! epoch's wall time as its `seconds` (the latency that request
//! actually observed).

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::arch::Arch;
use crate::gemm::{ConfigMode, GemmBatchItem, Lookahead};
use crate::model::batchplan::{BatchPlanner, BatchPolicy};
use crate::model::GemmDims;
use crate::runtime::pool::WorkerPool;

use super::metrics::Metrics;
use super::requests::{DlaRequest, DlaResponse};
use super::Coordinator;

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub arch: Arch,
    pub mode: ConfigMode,
    /// Channel capacity (backpressure bound).
    pub queue_depth: usize,
    /// Width of the shared intra-request GEMM pool (1 = sequential GEMMs).
    pub gemm_threads: usize,
    /// Lookahead policy for blocked factorization requests; `None` keeps
    /// the engine heuristic (and the `DLA_LOOKAHEAD` env override).
    pub lookahead: Option<Lookahead>,
    /// Batching policy for small GEMM requests; `None` defers to the
    /// `DLA_BATCH` environment override (pin
    /// [`crate::model::BatchPolicy::disabled`] to force batching off).
    pub batching: Option<BatchPolicy>,
}

impl ServerConfig {
    pub fn new(arch: Arch, mode: ConfigMode) -> Self {
        Self {
            workers: 1,
            arch,
            mode,
            queue_depth: 64,
            gemm_threads: 1,
            lookahead: None,
            batching: None,
        }
    }

    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Share one persistent `n`-thread GEMM pool across all workers.
    pub fn with_gemm_threads(mut self, n: usize) -> Self {
        self.gemm_threads = n.max(1);
        self
    }

    /// Pin the lookahead policy every worker engine serves with.
    pub fn with_lookahead(mut self, la: Lookahead) -> Self {
        self.lookahead = Some(la);
        self
    }

    /// Pin the batching policy (see the module docs). A pinned policy
    /// always wins over the `DLA_BATCH` environment override.
    pub fn with_batching(mut self, policy: BatchPolicy) -> Self {
        self.batching = Some(policy);
        self
    }
}

type Job = (DlaRequest, mpsc::Sender<anyhow::Result<DlaResponse>>);

/// One admitted request parked in the admission queue (always a
/// `DlaRequest::Gemm` — admission guarantees it), with everything needed
/// to execute and answer it.
struct PendingGemm {
    req: DlaRequest,
    reply: mpsc::Sender<anyhow::Result<DlaResponse>>,
    enqueued: Instant,
}

struct Bucket {
    /// Enqueue time of the oldest entry (the dispatch deadline anchor).
    first_at: Instant,
    entries: Vec<PendingGemm>,
}

#[derive(Default)]
struct QueueState {
    buckets: HashMap<GemmDims, Bucket>,
    /// Entries across all buckets (the backpressure bound).
    pending: usize,
    closed: bool,
}

/// The admission queue of the batch scheduler: workers push admitted
/// small GEMMs in (bucketed by shape), the batcher thread pulls whole
/// buckets out when they are worth dispatching. Total parked entries are
/// bounded by `max_pending` so the admission queue cannot defeat the
/// bounded request channel's backpressure — an over-limit request is
/// handed back to the worker, which serves it solo.
struct BatchQueue {
    policy: BatchPolicy,
    max_pending: usize,
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl BatchQueue {
    fn new(policy: BatchPolicy, max_pending: usize) -> Self {
        Self {
            policy,
            max_pending: max_pending.max(policy.max_batch),
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
        }
    }

    /// Park an admitted request, or hand it back when the queue is at
    /// its backpressure bound or already closed (`Err` = caller must
    /// serve it solo). The closed check matters when the server is
    /// dropped without `shutdown()`: the batcher may already be gone,
    /// and a parked entry would never be answered.
    fn try_enqueue(&self, dims: GemmDims, entry: PendingGemm) -> Result<(), PendingGemm> {
        let wake = {
            let mut st = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if st.closed || st.pending >= self.max_pending {
                return Err(entry);
            }
            st.pending += 1;
            let first_at = entry.enqueued;
            let created = !st.buckets.contains_key(&dims);
            let bucket = st
                .buckets
                .entry(dims)
                .or_insert_with(|| Bucket { first_at, entries: Vec::new() });
            bucket.entries.push(entry);
            // Only a new bucket (fresh deadline) or a full one changes
            // what the batcher would do; appending to a non-full bucket
            // needs no wakeup.
            created || bucket.entries.len() >= self.policy.max_batch
        };
        if wake {
            self.cv.notify_all();
        }
        Ok(())
    }

    /// No more enqueuers exist: wake the batcher so it flushes every
    /// remaining bucket (ignoring the coalescing wait) and exits.
    fn close(&self) {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner).closed = true;
        self.cv.notify_all();
    }

    /// Block until a bucket is dispatchable — full (`>= max_batch`),
    /// expired (oldest entry waited `wait_us`), or anything at all once
    /// closed — and take the whole bucket. Oldest bucket first, so no
    /// shape can be starved by a hot one. Returns `None` when closed and
    /// fully drained.
    fn next_batch(&self) -> Option<Vec<PendingGemm>> {
        let mut st = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        loop {
            let now = Instant::now();
            let ready = st
                .buckets
                .iter()
                .filter(|(_, b)| {
                    st.closed
                        || b.entries.len() >= self.policy.max_batch
                        || now.duration_since(b.first_at) >= self.policy.wait()
                })
                .min_by_key(|(_, b)| b.first_at)
                .map(|(&dims, _)| dims);
            if let Some(dims) = ready {
                let bucket = st.buckets.remove(&dims).expect("ready bucket vanished");
                st.pending -= bucket.entries.len();
                return Some(bucket.entries);
            }
            if st.closed {
                return None; // closed and drained
            }
            // Sleep until the nearest deadline; with nothing parked,
            // park outright (enqueue/close always notify).
            let deadline = st
                .buckets
                .values()
                .map(|b| (b.first_at + self.policy.wait()).saturating_duration_since(now))
                .min();
            st = match deadline {
                Some(timeout) => {
                    let (guard, _) = self
                        .cv
                        .wait_timeout(st, timeout.max(Duration::from_micros(1)))
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    guard
                }
                None => self.cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner),
            };
        }
    }
}

/// The batcher thread: owns its own coordinator (engine + metrics) on
/// the shared pool, turns dispatchable buckets into fused
/// [`crate::gemm::GemmEngine::gemm_batch`] epochs, and answers every
/// member's reply channel. Returns its metrics at exit for the shutdown
/// merge.
fn batcher_loop(
    queue: Arc<BatchQueue>,
    arch: Arch,
    mode: ConfigMode,
    pool: Option<Arc<WorkerPool>>,
) -> Metrics {
    let mut co = Coordinator::new(arch, mode);
    if let Some(pool) = pool {
        co = co.with_pool(pool);
    }
    while let Some(mut entries) = queue.next_batch() {
        let t0 = Instant::now();
        let waits: Vec<u64> =
            entries.iter().map(|e| t0.duration_since(e.enqueued).as_nanos() as u64).collect();
        let mut items: Vec<GemmBatchItem<'_>> = entries
            .iter_mut()
            .map(|e| {
                let DlaRequest::Gemm { alpha, a, b, beta, c } = &mut e.req else {
                    unreachable!("only Gemm requests are admitted");
                };
                GemmBatchItem { alpha: *alpha, a: a.view(), b: b.view(), beta: *beta, c: c.view_mut() }
            })
            .collect();
        let configs = co.engine.gemm_batch(&mut items);
        drop(items);
        let dt = t0.elapsed().as_secs_f64();
        co.metrics.record_batch_dispatch(entries.len(), &waits);
        for (e, cfg) in entries.into_iter().zip(configs) {
            let flops = e.req.flops();
            let DlaRequest::Gemm { c, .. } = e.req else {
                unreachable!("only Gemm requests are admitted");
            };
            // Every member of the fused epoch observed the epoch's wall
            // time as its service latency.
            co.metrics.record("gemm", dt, flops);
            let _ = e.reply.send(Ok(DlaResponse::Matrix {
                result: c,
                config: Some(cfg.to_string()),
                seconds: dt,
            }));
        }
        co.snapshot_pool_stats();
    }
    co.metrics
}

/// A running coordinator server.
pub struct CoordinatorServer {
    tx: Option<mpsc::SyncSender<Job>>,
    handles: Vec<thread::JoinHandle<Metrics>>,
    batch_queue: Option<Arc<BatchQueue>>,
    batch_handle: Option<thread::JoinHandle<Metrics>>,
}

impl CoordinatorServer {
    /// Start `cfg.workers` worker threads (plus, when `gemm_threads > 1`,
    /// one shared persistent GEMM pool spawned here, once; plus, with
    /// batching enabled, one batcher thread draining the admission
    /// queue).
    ///
    /// Panics **on the caller's thread** when the pinned lookahead
    /// policy is invalid for `gemm_threads` — otherwise the engine-level
    /// validation would fire inside every detached worker and the
    /// misconfiguration would only surface as dead request channels.
    pub fn start(cfg: ServerConfig) -> Self {
        if let Some(la) = cfg.lookahead {
            if let Err(e) = la.validate(cfg.gemm_threads.max(1)) {
                panic!("invalid lookahead policy for this server config: {e}");
            }
        }
        // A pinned batching policy always wins (so BatchPolicy::disabled()
        // really disables); un-pinned servers take the env override. On a
        // 1-thread pool admission can never succeed (is_batchable needs a
        // team to waste), so no queue or batcher thread is created at all.
        let batching = cfg
            .batching
            .or_else(BatchPolicy::from_env)
            .filter(BatchPolicy::enabled)
            .filter(|_| cfg.gemm_threads >= 2);
        let batch_queue =
            batching.map(|policy| Arc::new(BatchQueue::new(policy, cfg.queue_depth)));
        let (tx, rx) = mpsc::sync_channel::<Job>(cfg.queue_depth);
        let rx = std::sync::Arc::new(std::sync::Mutex::new(rx));
        let gemm_pool =
            (cfg.gemm_threads > 1).then(|| Arc::new(WorkerPool::new(cfg.gemm_threads)));
        let gemm_threads = cfg.gemm_threads.max(1);
        let mut handles = Vec::new();
        for i in 0..cfg.workers {
            let rx = rx.clone();
            let arch = cfg.arch.clone();
            let mode = cfg.mode.clone();
            let pool = gemm_pool.clone();
            let lookahead = cfg.lookahead;
            let queue = batch_queue.clone();
            handles.push(
                thread::Builder::new()
                    .name(format!("dla-worker-{i}"))
                    .spawn(move || {
                        let mut co = Coordinator::new(arch, mode);
                        if let Some(pool) = pool {
                            co = co.with_pool(pool);
                        }
                        if let Some(la) = lookahead {
                            co = co.with_lookahead(la);
                        }
                        // Per-worker admission memo (scorer runs once per
                        // distinct shape, not once per request).
                        let planner = BatchPlanner::new();
                        loop {
                            // Hold the lock only while receiving.
                            let job = { rx.lock().unwrap().recv() };
                            match job {
                                Ok((req, reply)) => {
                                    // Admission: route model-judged-small,
                                    // well-formed GEMMs into the batcher;
                                    // everything else (factorizations,
                                    // large GEMMs) keeps the solo path.
                                    if let Some(q) = &queue {
                                        if let Some(dims) = req.gemm_dims() {
                                            let admit = req.gemm_shape_consistent()
                                                && planner.is_batchable(
                                                    &co.engine.arch,
                                                    co.engine.plan_config(dims),
                                                    dims,
                                                    gemm_threads,
                                                    &q.policy,
                                                );
                                            if admit {
                                                let entry = PendingGemm {
                                                    req,
                                                    reply,
                                                    enqueued: Instant::now(),
                                                };
                                                if let Err(e) = q.try_enqueue(dims, entry) {
                                                    // Queue at its backpressure
                                                    // bound (or closed): serve
                                                    // solo.
                                                    let resp = co.handle(e.req);
                                                    let _ = e.reply.send(resp);
                                                }
                                                continue;
                                            }
                                        }
                                    }
                                    let resp = co.handle(req);
                                    let _ = reply.send(resp);
                                }
                                Err(_) => break, // channel closed: drain done
                            }
                        }
                        co.metrics
                    })
                    .expect("spawning server worker"),
            );
        }
        let batch_handle = batch_queue.as_ref().map(|q| {
            let queue = Arc::clone(q);
            let arch = cfg.arch.clone();
            let mode = cfg.mode.clone();
            let pool = gemm_pool.clone();
            thread::Builder::new()
                .name("dla-batcher".to_string())
                .spawn(move || batcher_loop(queue, arch, mode, pool))
                .expect("spawning batcher")
        });
        Self { tx: Some(tx), handles, batch_queue, batch_handle }
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: DlaRequest) -> mpsc::Receiver<anyhow::Result<DlaResponse>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("server already shut down")
            .send((req, reply_tx))
            .expect("worker pool gone");
        reply_rx
    }

    /// Submit and wait.
    pub fn call(&self, req: DlaRequest) -> anyhow::Result<DlaResponse> {
        self.submit(req).recv().expect("worker dropped reply channel")
    }

    /// Shut down and merge worker (and batcher) metrics.
    ///
    /// # Drain semantics
    ///
    /// Every request accepted by [`Self::submit`] is served before any
    /// thread is joined — nothing is dropped, in two stages:
    ///
    /// 1. **Channel drain.** Dropping the sender makes each worker's
    ///    `recv` yield every already-queued request before reporting
    ///    disconnect, so workers finish (or route into the batcher) all
    ///    of them and only then exit; joining here cannot strand queued
    ///    work.
    /// 2. **Admission-queue drain.** Only after every worker has exited
    ///    (i.e. no enqueuer remains) is the batch queue closed; `close`
    ///    makes the batcher flush every pending bucket immediately —
    ///    ignoring the coalescing wait — answer the replies, and exit.
    ///
    /// The returned metrics merge every worker's counters plus the
    /// batcher's (batched GEMM latencies, [`super::metrics::BatchMetrics`],
    /// and the latest shared-pool idle snapshot).
    pub fn shutdown(mut self) -> Metrics {
        drop(self.tx.take());
        let mut all = Metrics::new();
        for h in self.handles.drain(..) {
            all.merge(h.join().expect("worker panicked"));
        }
        if let Some(q) = self.batch_queue.take() {
            q.close();
        }
        if let Some(h) = self.batch_handle.take() {
            all.merge(h.join().expect("batcher panicked"));
        }
        all
    }
}

impl Drop for CoordinatorServer {
    /// Dropping without [`Self::shutdown`] must not leak threads: close
    /// the channel and the admission queue so workers and the batcher
    /// unblock and exit (releasing their `Arc` on the shared pool, whose
    /// own `Drop` then retires the team). Metrics are lost and the
    /// threads are detached, not joined — call `shutdown` for the
    /// orderly two-stage drain. After `shutdown` every field is already
    /// `None` and this is a no-op.
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(q) = self.batch_queue.take() {
            q.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::util::{MatrixF64, Pcg64};

    fn gemm_req(rng: &mut Pcg64, m: usize, n: usize, k: usize) -> DlaRequest {
        DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::random(m, k, rng),
            b: MatrixF64::random(k, n, rng),
            beta: 0.0,
            c: MatrixF64::zeros(m, n),
        }
    }

    #[test]
    fn server_round_trip() {
        let server = CoordinatorServer::start(ServerConfig::new(host_xeon(), ConfigMode::Refined));
        let mut rng = Pcg64::seed(9);
        let resp = server.call(gemm_req(&mut rng, 30, 20, 10)).unwrap();
        assert!(resp.seconds() >= 0.0);
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
    }

    #[test]
    fn server_multiple_workers_process_all() {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined).with_workers(3),
        );
        let mut rng = Pcg64::seed(10);
        let mut pending = Vec::new();
        for i in 0..12 {
            let sz = 16 + (i % 4) * 8;
            pending.push(server.submit(gemm_req(&mut rng, sz, sz, 8)));
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 12);
    }

    #[test]
    fn server_shares_one_gemm_pool_across_workers() {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3),
        );
        let mut rng = Pcg64::seed(11);
        let mut pending = Vec::new();
        for _ in 0..6 {
            pending.push(server.submit(gemm_req(&mut rng, 48, 40, 16)));
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 6);
    }

    #[test]
    fn server_reports_pool_idle_stats_and_serves_lookahead_lu() {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_lookahead(Lookahead { depth: 1, panel_workers: 1 }),
        );
        let mut rng = Pcg64::seed(12);
        let a = MatrixF64::random_diag_dominant(64, &mut rng);
        let resp = server.call(DlaRequest::LuFactor { a: a.clone(), block: 16 }).unwrap();
        let DlaResponse::Lu { factors, .. } = resp else { panic!() };
        assert!(factors.reconstruction_error(&a) < 1e-10);
        let metrics = server.shutdown();
        let pool = metrics.pool_stats().expect("pooled server must surface pool stats");
        assert!(pool.jobs > 0, "LU trailing updates must have run pooled jobs: {pool:?}");
        assert!(metrics.summary().contains("gemm pool:"));
    }

    #[test]
    #[should_panic(expected = "invalid lookahead policy for this server config")]
    fn server_rejects_invalid_lookahead_up_front() {
        // The panic must fire on the caller's thread at start(), not
        // inside detached workers.
        let _ = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_lookahead(Lookahead { depth: 1, panel_workers: 3 }),
        );
    }

    #[test]
    fn server_serves_both_dtypes_on_one_shared_pool() {
        use crate::util::MatrixF32;
        // One 3-thread pool; f64 GEMM + f32 GEMM + mixed-precision solve
        // all flow through it (the mixed solve factors in f32 on the
        // pooled pipeline and refines with f64 pooled GEMMs).
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3),
        );
        let mut rng = Pcg64::seed(31);
        let g64 = server.submit(gemm_req(&mut rng, 64, 48, 16));
        let a32 = MatrixF32::random(64, 24, &mut rng);
        let b32 = MatrixF32::random(24, 48, &mut rng);
        let g32 = server.submit(DlaRequest::GemmF32 {
            alpha: 1.0,
            a: a32.clone(),
            b: b32.clone(),
            beta: 0.0,
            c: MatrixF32::zeros(64, 48),
        });
        let a = crate::util::MatrixF64::random_diag_dominant(96, &mut rng);
        let x_true = crate::util::MatrixF64::random(96, 1, &mut rng);
        let mut rhs = crate::util::MatrixF64::zeros(96, 1);
        crate::gemm::gemm_reference(1.0, a.view(), x_true.view(), 0.0, &mut rhs.view_mut());
        let mx = server.submit(DlaRequest::MixedSolve { a, rhs, block: 24 });
        g64.recv().unwrap().unwrap();
        let DlaResponse::MatrixF32 { result, .. } = g32.recv().unwrap().unwrap() else {
            panic!()
        };
        let mut expect = MatrixF32::zeros(64, 48);
        crate::gemm::gemm_reference(1.0f32, a32.view(), b32.view(), 0.0f32, &mut expect.view_mut());
        assert!(result.max_abs_diff(&expect) < 1e-3);
        let DlaResponse::MixedSolve { x, fell_back, residual, .. } = mx.recv().unwrap().unwrap()
        else {
            panic!()
        };
        assert!(!fell_back);
        assert!(residual <= 1e-10, "{residual}");
        assert!(x.max_abs_diff(&x_true) < 1e-8);
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
        assert_eq!(metrics.count("gemm_f32"), 1);
        assert_eq!(metrics.count("mixed_lu"), 1);
        assert_eq!(metrics.refine_stats().solves, 1);
        let pool = metrics.pool_stats().expect("pooled server must surface pool stats");
        assert!(pool.jobs > 0, "both dtypes must have dispatched pooled jobs: {pool:?}");
        assert!(metrics.summary().contains("mixed precision:"));
    }

    #[test]
    fn server_propagates_errors() {
        let server = CoordinatorServer::start(ServerConfig::new(host_xeon(), ConfigMode::Refined));
        let resp = server.call(DlaRequest::LuFactor { a: MatrixF64::zeros(6, 6), block: 2 });
        assert!(resp.is_err());
        server.shutdown();
    }

    #[test]
    fn batching_server_coalesces_small_gemms() {
        // A long wait + a small full-trigger: the only way requests get
        // served promptly is the full-bucket dispatch, so coalescing is
        // deterministic (the remainder flushes at shutdown).
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3)
                .with_batching(BatchPolicy::default().with_max_batch(4).with_wait_us(5_000_000).admit_all()),
        );
        let mut rng = Pcg64::seed(21);
        let mut pending = Vec::new();
        for _ in 0..8 {
            pending.push(server.submit(gemm_req(&mut rng, 24, 24, 12)));
        }
        // Shutdown drains everything (including a not-yet-full remainder
        // bucket), so the replies are all available afterwards.
        let metrics = server.shutdown();
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(metrics.count("gemm"), 8);
        let b = metrics.batch_stats();
        assert_eq!(b.total_requests(), 8, "every small gemm goes through the batcher: {b:?}");
        assert!(b.batches >= 1, "the full trigger must have fired: {b:?}");
        // The first full-bucket dispatch alone coalesces max_batch
        // requests.
        assert!(b.coalesced_requests >= 4, "{b:?}");
        assert_eq!(b.queue_wait_ns.count, 8);
        assert!(metrics.summary().contains("batching:"));
    }

    #[test]
    fn batch_queue_bounds_pending_entries() {
        // The admission queue must preserve the server's backpressure: at
        // the bound, try_enqueue hands the entry back (the worker serves
        // it solo); draining a bucket frees capacity.
        let q = BatchQueue::new(BatchPolicy::default().with_max_batch(2), 2);
        let dims = GemmDims::new(8, 8, 8);
        let entry = || PendingGemm {
            req: DlaRequest::Gemm {
                alpha: 1.0,
                a: MatrixF64::zeros(8, 8),
                b: MatrixF64::zeros(8, 8),
                beta: 0.0,
                c: MatrixF64::zeros(8, 8),
            },
            reply: mpsc::channel().0,
            enqueued: Instant::now(),
        };
        assert!(q.try_enqueue(dims, entry()).is_ok());
        assert!(q.try_enqueue(dims, entry()).is_ok());
        assert!(q.try_enqueue(dims, entry()).is_err(), "bound must reject the third entry");
        // The full bucket is dispatchable; draining frees capacity.
        let batch = q.next_batch().expect("full bucket ready");
        assert_eq!(batch.len(), 2);
        assert!(q.try_enqueue(dims, entry()).is_ok());
    }

    #[test]
    fn pinned_disabled_batching_beats_env() {
        // BatchPolicy::disabled() must force the solo path even when the
        // CI matrix exports DLA_BATCH=1.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_batching(BatchPolicy::disabled()),
        );
        let mut rng = Pcg64::seed(22);
        server.call(gemm_req(&mut rng, 24, 24, 12)).unwrap();
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
        assert_eq!(metrics.batch_stats().total_requests(), 0);
    }

    #[test]
    fn factorizations_bypass_the_batcher() {
        // With an hour-long coalescing window, a batched request would
        // visibly hang — factorizations must come back via the solo path
        // immediately, composing with lookahead on the shared pool.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_batching(BatchPolicy::default().with_wait_us(3_600_000_000).admit_all()),
        );
        let mut rng = Pcg64::seed(23);
        let a = MatrixF64::random_diag_dominant(48, &mut rng);
        let resp = server.call(DlaRequest::LuFactor { a: a.clone(), block: 16 }).unwrap();
        let DlaResponse::Lu { factors, .. } = resp else { panic!() };
        assert!(factors.reconstruction_error(&a) < 1e-10);
        let metrics = server.shutdown();
        assert_eq!(metrics.count("lu"), 1);
        assert_eq!(metrics.batch_stats().total_requests(), 0, "LU must not touch the batcher");
    }
}
