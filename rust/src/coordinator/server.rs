//! A worker-thread request loop around the [`super::Coordinator`]:
//! requests flow through a bounded channel (backpressure), each worker
//! owns its engine (and thus its workspace pool and config-selection memo
//! cache), and per-worker metrics are merged at shutdown.
//!
//! With [`ServerConfig::with_gemm_threads`] the server provisions **one**
//! persistent GEMM worker pool at startup and shares it across every
//! request worker's engine: heavy requests get intra-request parallelism,
//! the team is spawned exactly once for the lifetime of the server (pool
//! `run`s from different workers serialize on the pool's leader lock, so
//! the machine is never oversubscribed), and no request ever pays thread
//! creation cost.
//!
//! # The batched request scheduler
//!
//! With batching enabled ([`ServerConfig::with_batching`], or the
//! `DLA_BATCH` / `DLA_BATCH_WAIT_US` environment knobs on un-pinned
//! servers), small GEMM requests no longer each run one whole pool
//! dispatch under the leader lock. Instead the request path becomes:
//!
//! 1. **Admission.** A worker pulls a request from the channel as usual,
//!    but routes it into the admission queue when the
//!    [`crate::model::batchplan`] cost model says a full-team dispatch
//!    would waste the machine (estimated single-core time below the
//!    policy threshold, or a G4 grain too small to feed the team). The
//!    queue **buckets by dtype and problem shape** (an f64 and an f32
//!    GEMM of the same shape never coalesce); factorizations and large
//!    GEMMs bypass the batcher entirely and keep the existing (lookahead)
//!    path — the two schedulers compose on one shared pool. Parked
//!    entries are bounded by `queue_depth` (preserving the channel's
//!    backpressure); at the bound, requests are served solo. Requests
//!    whose deadline is tighter than the coalescing window also bypass
//!    the batcher ([`BatchPolicy::fits_deadline`]) — coalescing trades
//!    latency for throughput, and a deadline caps that trade.
//! 2. **Coalescing.** A dedicated batcher thread sleeps until a bucket
//!    is dispatchable: it reached `max_batch` entries, its oldest entry
//!    has waited `wait_us`, or the server is shutting down.
//! 3. **Fused dispatch.** The bucket is executed as one (or, above the
//!    team width, a few chunked) fused pool epoch(s) via
//!    [`crate::gemm::GemmEngine::gemm_batch`]: the team is partitioned
//!    across the batch members by the same cost model, every member
//!    keeps its own memoized per-shape configuration, and each result is
//!    **bitwise identical** to what a solo dispatch would have produced
//!    (asserted by `tests/batching.rs`).
//!
//! Per-batch observability (dispatch-size histogram, coalesced-vs-solo
//! counts, per-request queue wait) is recorded in
//! [`super::metrics::BatchMetrics`] and merged into the server metrics
//! at shutdown. A response served from a fused dispatch reports the
//! epoch's wall time as its `seconds` (the latency that request
//! actually observed).
//!
//! # Fault tolerance
//!
//! The serving path degrades instead of dying (see the failure-model
//! section of `lapack/README.md` for the full ladder):
//!
//! - **Admission validation.** [`Self::submit`] rejects malformed
//!   requests (NaN/Inf operands, shape mismatches) with
//!   [`DlaError::InvalidInput`] *before* they consume queue capacity.
//! - **Deadlines.** [`ServerConfig::with_deadline`] (or
//!   `DLA_DEADLINE_MS`) bounds every request end to end: expired
//!   requests are dropped at dequeue (and in the batcher) with
//!   [`DlaError::Timeout`], and [`Self::call`] stops waiting at the
//!   deadline instead of blocking forever on a stalled worker.
//! - **Backpressure retries.** A full channel is transient:
//!   [`Self::submit`] retries with bounded, jittered exponential backoff
//!   before giving up with [`DlaError::QueueFull`].
//! - **Panic isolation + degraded mode.** A request whose handler
//!   panics is answered with [`DlaError::Internal`] (the worker thread
//!   survives via `catch_unwind`; the shared pool has already recovered
//!   its epoch — see `runtime::pool`). The next
//!   [`DEGRADED_WINDOW`] requests are then served by a pool-less serial
//!   coordinator — bitwise identical results at reduced throughput —
//!   before the worker resumes trusting the pooled path.
//! - **Poison-tolerant shutdown.** [`Self::shutdown`] never unwraps a
//!   `join`: a dead worker is counted as `workers_lost` and the
//!   surviving workers' metrics are still merged.
//! - **Verified compute.** With [`ServerConfig::with_verify`] (or
//!   `DLA_VERIFY=detect|correct`) every worker engine runs its GEMMs
//!   and factorization trailing updates checksum-verified (ABFT): a
//!   silent bit flip in a packed operand or an accumulator is detected,
//!   in `correct` mode repaired by a one-shot recompute of the affected
//!   tile, and anything unrepaired is answered as typed
//!   [`DlaError::DataCorrupt`] — never a silently wrong matrix.
//!   Verification counters land in [`super::metrics::AbftMetrics`] (the
//!   `abft:` summary line); batching is disabled under verification
//!   (the fused batch driver is unverified by design).
//!
//! Every fault is counted in [`super::metrics::FaultMetrics`] (the
//! `resilience:` summary line). Fault *injection* for drills and the
//! chaos suite is armed with [`ServerConfig::with_faults`] or the
//! `DLA_FAULTS` environment knob (see `runtime::faults`).
//!
//! # QoS tiers and overload resilience
//!
//! Surviving faults is not the same as surviving *demand*: when offered
//! load exceeds the pool's capacity, something has to give, and the
//! server makes that choice by policy instead of by queue order (see
//! [`super::qos`] for the machinery):
//!
//! - **Priority tiers.** Every request rides a [`Priority`] tier
//!   (`submit_at` / `submit_async_at`; the per-server default comes from
//!   [`ServerConfig::with_default_priority`] or `DLA_PRIORITY`, falling
//!   back to `Interactive`). The request queue is a tiered
//!   [`QosQueue`] with weighted-fair dequeue (weights 4/2/1) and a hard
//!   starvation bound; the batch scheduler's bucket picker applies the
//!   same credits across bucket *classes* (a bucket's class is its
//!   highest-priority member), so neither scheduler can starve a tier.
//! - **Async handles.** [`Self::submit_async`] returns a [`JobHandle`]
//!   that can be polled, waited on with a deadline, or cancelled.
//!   Cancellation of still-queued work is guaranteed (the worker
//!   observes the cancel flag before starting and answers
//!   [`DlaError::Cancelled`]); in-flight work runs to completion.
//! - **Per-tier retry budgets.** A full queue is retried with the same
//!   jittered backoff as before, but the budget is tiered
//!   (Interactive 8 / Batch 4 / Background 2 attempts): low-priority
//!   retries must not amplify an overload.
//! - **Adaptive shedding.** An [`OverloadDetector`] compares the
//!   smoothed measured queue wait against the smoothed service cost
//!   (the larger of the `BatchPlanner` analytic estimate and measured
//!   wall time). When waits outrun cost ~4×, Background submissions are
//!   shed at admission with typed [`DlaError::Overloaded`]; ~12×, Batch
//!   is shed too. Interactive is never shed — shedding exists to protect
//!   its deadlines. Every shed is counted per tier
//!   ([`super::metrics::QosMetrics`]) and the ledger reconciles:
//!   `submitted == completed + failed + shed + rejected + cancelled`.
//! - **Brownout.** At the severe level a handler panic widens the
//!   degraded window by [`OverloadLevel::brownout_factor`] (default ×4)
//!   instead of letting the server oscillate between the pooled path and
//!   fresh panics. The window length itself is configurable
//!   ([`ServerConfig::with_degraded_window`] / `DLA_DEGRADED_WINDOW`).
//!
//! # Measurement-calibrated selection
//!
//! With [`ServerConfig::with_calibration`] (or `DLA_CALIBRATE=1`) every
//! worker engine and the batcher share one
//! [`PerfProfile`](crate::model::PerfProfile): pool-epoch timings
//! recorded by the engines refine the analytic config/team-size/
//! admission scores online (confidence-weighted blending — see
//! `crate::model::profile`), `DLA_PROFILE=path` persists the store
//! across processes (loaded at [`CoordinatorServer::start`], saved at
//! [`CoordinatorServer::shutdown`]), and bounded deterministic
//! exploration occasionally tries the runner-up configuration — never
//! for Interactive-tier requests, and never in the batcher (a fused
//! bucket may carry Interactive members). Off (the default) attaches
//! nothing: selections are bitwise identical to the pure-analytic path
//! and the timing hooks never fire. The degraded serial fallback
//! coordinator also stays pure-analytic by design — a post-panic
//! cooldown is the wrong place to learn from timings. Calibration
//! counters land in [`super::metrics::CalibrationMetrics`] (the
//! `calibration:` summary line).
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::arch::Arch;
use crate::gemm::{ConfigMode, GemmBatchItem, Lookahead, VerifyPolicy};
use crate::model::batchplan::{BatchPlanner, BatchPolicy};
use crate::model::{CalibratePolicy, GemmDims, PerfProfile};
use crate::runtime::faults::{FaultPlan, FaultState};
use crate::runtime::pool::WorkerPool;
use crate::util::error::{panic_reason, DlaError};
use crate::util::DType;

use super::metrics::Metrics;
use super::qos::{OverloadDetector, OverloadLevel, Priority, PushError, QosQueue, TierCounters};
use super::requests::{DlaRequest, DlaResponse};
use super::Coordinator;

/// Default degraded-window length: how many requests a worker serves on
/// the pool-less serial fallback path after isolating a handler panic,
/// before trusting the pooled path again. The serial blocked path is
/// bitwise identical to the pooled one (asserted by `tests/chaos.rs`),
/// so correctness is never degraded — only throughput. Override with
/// [`ServerConfig::with_degraded_window`] or `DLA_DEGRADED_WINDOW`.
pub const DEGRADED_WINDOW: u64 = 8;

/// Admission attempts before a persistently full queue turns into
/// [`DlaError::QueueFull`] (initial try + retries with backoff). This is
/// the **Interactive** tier's budget — the legacy single-tier behavior;
/// lower tiers run tighter budgets (see
/// [`Priority::admission_attempts`], asserted equal in the tests).
const MAX_ADMISSION_ATTEMPTS: u32 = 8;

/// Default backoff-jitter seed (an arbitrary odd constant — the stream
/// only decorrelates concurrent submitters). Override per server with
/// [`ServerConfig::with_jitter_seed`] to make retry drills
/// deterministic.
const DEFAULT_JITTER_SEED: u64 = 0x243F_6A88_85A3_08D3;

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub arch: Arch,
    pub mode: ConfigMode,
    /// Channel capacity (backpressure bound).
    pub queue_depth: usize,
    /// Width of the shared intra-request GEMM pool (1 = sequential GEMMs).
    pub gemm_threads: usize,
    /// Lookahead policy for blocked factorization requests; `None` keeps
    /// the engine heuristic (and the `DLA_LOOKAHEAD` env override).
    pub lookahead: Option<Lookahead>,
    /// Batching policy for small GEMM requests; `None` defers to the
    /// `DLA_BATCH` environment override (pin
    /// [`crate::model::BatchPolicy::disabled`] to force batching off).
    pub batching: Option<BatchPolicy>,
    /// End-to-end deadline applied to every request; `None` defers to
    /// the `DLA_DEADLINE_MS` environment override (unset = no deadline).
    pub deadline: Option<Duration>,
    /// Fault-injection plan for drills and the chaos suite; `None`
    /// defers to the `DLA_FAULTS` environment override (unset = hooks
    /// un-armed, zero cost).
    pub faults: Option<FaultPlan>,
    /// Degraded-window length armed by a handler panic; `None` defers to
    /// the `DLA_DEGRADED_WINDOW` environment override, then
    /// [`DEGRADED_WINDOW`].
    pub degraded_window: Option<u64>,
    /// Default [`Priority`] for `submit` / `submit_async`; `None` defers
    /// to the `DLA_PRIORITY` environment override, then
    /// `Priority::Interactive`.
    pub default_priority: Option<Priority>,
    /// ABFT verification policy applied to every worker engine; `None`
    /// defers to the `DLA_VERIFY` environment override, then
    /// [`VerifyPolicy::Off`].
    pub verify: Option<VerifyPolicy>,
    /// Seed for the admission backoff's jitter stream; `None` keeps the
    /// built-in constant. Pin a seed per test to make retry drills
    /// deterministic (jitter only decorrelates concurrent submitters —
    /// any seed is as good as any other in production).
    pub jitter_seed: Option<u64>,
    /// Measurement-calibrated selection policy; `None` defers to the
    /// `DLA_CALIBRATE` environment override, then `Off`. Off (the
    /// default) means no profile is attached anywhere: selections are
    /// bitwise identical to the pure-analytic path and the timing hooks
    /// never fire.
    pub calibration: Option<CalibratePolicy>,
}

impl ServerConfig {
    pub fn new(arch: Arch, mode: ConfigMode) -> Self {
        Self {
            workers: 1,
            arch,
            mode,
            queue_depth: 64,
            gemm_threads: 1,
            lookahead: None,
            batching: None,
            deadline: None,
            faults: None,
            degraded_window: None,
            default_priority: None,
            verify: None,
            jitter_seed: None,
            calibration: None,
        }
    }

    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Share one persistent `n`-thread GEMM pool across all workers.
    pub fn with_gemm_threads(mut self, n: usize) -> Self {
        self.gemm_threads = n.max(1);
        self
    }

    /// Pin the lookahead policy every worker engine serves with.
    pub fn with_lookahead(mut self, la: Lookahead) -> Self {
        self.lookahead = Some(la);
        self
    }

    /// Pin the batching policy (see the module docs). A pinned policy
    /// always wins over the `DLA_BATCH` environment override.
    pub fn with_batching(mut self, policy: BatchPolicy) -> Self {
        self.batching = Some(policy);
        self
    }

    /// Bound every request end to end: expired requests are answered
    /// with [`DlaError::Timeout`] instead of being served late, and
    /// [`CoordinatorServer::call`] stops waiting at the deadline. A
    /// pinned deadline wins over the `DLA_DEADLINE_MS` override.
    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Arm a fault-injection plan (chaos drills; see `runtime::faults`).
    /// A pinned plan wins over the `DLA_FAULTS` override.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Pin the degraded-window length (requests served on the serial
    /// fallback after a handler panic). A pinned window wins over the
    /// `DLA_DEGRADED_WINDOW` override; clamped to at least 1 so a panic
    /// always buys *some* cooldown.
    pub fn with_degraded_window(mut self, n: u64) -> Self {
        self.degraded_window = Some(n.max(1));
        self
    }

    /// Pin the default QoS tier used by `submit` / `submit_async` when
    /// the caller does not name one. A pinned tier wins over the
    /// `DLA_PRIORITY` override.
    pub fn with_default_priority(mut self, tier: Priority) -> Self {
        self.default_priority = Some(tier);
        self
    }

    /// Pin the ABFT verification policy every worker engine serves with
    /// (see [`VerifyPolicy`]): `Detect` turns silent data corruption
    /// into typed [`DlaError::DataCorrupt`] responses, `Correct` also
    /// recomputes corrupted packed-operand tiles once. A pinned policy
    /// wins over the `DLA_VERIFY` override. With verification enabled
    /// the batch scheduler is disabled — every GEMM takes the verified
    /// solo path (the fused batch driver is unverified by design).
    pub fn with_verify(mut self, policy: VerifyPolicy) -> Self {
        self.verify = Some(policy);
        self
    }

    /// Pin the jitter-stream seed used by admission backoff, making
    /// retry timing reproducible for drills and tests.
    pub fn with_jitter_seed(mut self, seed: u64) -> Self {
        self.jitter_seed = Some(seed);
        self
    }

    /// Pin the measurement-calibration policy (see
    /// `crate::model::profile`): `On` attaches one shared
    /// [`PerfProfile`] to every worker engine and the batcher, so epoch
    /// timings refine the analytic selection online. A pinned policy
    /// wins over the `DLA_CALIBRATE` override; pin
    /// [`CalibratePolicy::Off`] to force calibration off regardless of
    /// the environment.
    pub fn with_calibration(mut self, policy: CalibratePolicy) -> Self {
        self.calibration = Some(policy);
        self
    }
}

/// The `DLA_DEADLINE_MS` override: a positive integer arms a per-request
/// deadline on servers that did not pin one; unset / unparseable / `0`
/// means no deadline (a typo must fail toward "no new failure mode").
fn deadline_from_env() -> Option<Duration> {
    std::env::var("DLA_DEADLINE_MS")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .map(Duration::from_millis)
}

/// The `DLA_DEGRADED_WINDOW` override: a positive integer resizes the
/// post-panic serial window on servers that did not pin one; unset /
/// unparseable / `0` keeps the [`DEGRADED_WINDOW`] default (a typo must
/// not disable the cooldown).
fn degraded_window_from_env() -> Option<u64> {
    std::env::var("DLA_DEGRADED_WINDOW")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .filter(|&n| n > 0)
}

/// Cancellation state shared between a [`JobHandle`] and the worker that
/// eventually dequeues its job: a three-state flag (queued → claimed |
/// cancelled) advanced only by compare-and-swap, so exactly one side
/// wins. A worker that loses the race answers [`DlaError::Cancelled`]
/// without starting the work; a caller that loses observes the job
/// already claimed and the work runs to completion.
struct HandleCtrl(AtomicU8);

const CTRL_QUEUED: u8 = 0;
const CTRL_CLAIMED: u8 = 1;
const CTRL_CANCELLED: u8 = 2;

impl HandleCtrl {
    fn new() -> Self {
        Self(AtomicU8::new(CTRL_QUEUED))
    }

    /// Worker side: claim the job for execution. False when the caller
    /// cancelled first.
    fn claim(&self) -> bool {
        self.0
            .compare_exchange(CTRL_QUEUED, CTRL_CLAIMED, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Caller side: cancel the still-queued job. False when a worker
    /// already claimed it (or it was already cancelled).
    fn cancel(&self) -> bool {
        self.0
            .compare_exchange(CTRL_QUEUED, CTRL_CANCELLED, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }
}

/// One request in flight between `submit` and a worker.
struct Job {
    req: DlaRequest,
    /// The QoS tier the request was submitted at.
    tier: Priority,
    /// When `submit` accepted the request (the latency/timeout anchor).
    submitted: Instant,
    /// Absolute expiry, if the server has a deadline.
    deadline: Option<Instant>,
    reply: mpsc::Sender<Result<DlaResponse, DlaError>>,
    /// Cancellation flag, present only for `submit_async` jobs.
    ctrl: Option<Arc<HandleCtrl>>,
}

/// The admission queue's bucket key: only same-dtype, same-shape GEMMs
/// may coalesce into one fused dispatch.
type BucketKey = (DType, GemmDims);

/// One admitted request parked in the admission queue (always a
/// `DlaRequest::Gemm` or `DlaRequest::GemmF32`, matching its bucket's
/// dtype — admission guarantees it), with everything needed to execute
/// and answer it.
struct PendingGemm {
    req: DlaRequest,
    tier: Priority,
    reply: mpsc::Sender<Result<DlaResponse, DlaError>>,
    enqueued: Instant,
    deadline: Option<Instant>,
}

struct Bucket {
    /// Enqueue time of the oldest entry (the dispatch deadline anchor).
    first_at: Instant,
    entries: Vec<PendingGemm>,
}

#[derive(Default)]
struct QueueState {
    buckets: HashMap<BucketKey, Bucket>,
    /// Entries across all buckets (the backpressure bound).
    pending: usize,
    /// Weighted-fair credits across bucket *classes* (a bucket's class
    /// is its highest-priority member) — the same scheduler the request
    /// queue uses, so the batcher cannot starve a tier either.
    credits: super::qos::WeightedCredits,
    closed: bool,
}

/// The admission queue of the batch scheduler: workers push admitted
/// small GEMMs in (bucketed by dtype + shape), the batcher thread pulls whole
/// buckets out when they are worth dispatching. Total parked entries are
/// bounded by `max_pending` so the admission queue cannot defeat the
/// bounded request channel's backpressure — an over-limit request is
/// handed back to the worker, which serves it solo.
struct BatchQueue {
    policy: BatchPolicy,
    max_pending: usize,
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl BatchQueue {
    fn new(policy: BatchPolicy, max_pending: usize) -> Self {
        Self {
            policy,
            max_pending: max_pending.max(policy.max_batch),
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
        }
    }

    /// Park an admitted request, or hand it back when the queue is at
    /// its backpressure bound or already closed (`Err` = caller must
    /// serve it solo). The closed check matters when the server is
    /// dropped without `shutdown()`: the batcher may already be gone,
    /// and a parked entry would never be answered.
    fn try_enqueue(&self, key: BucketKey, entry: PendingGemm) -> Result<(), PendingGemm> {
        let wake = {
            let mut st = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if st.closed || st.pending >= self.max_pending {
                return Err(entry);
            }
            st.pending += 1;
            let first_at = entry.enqueued;
            let created = !st.buckets.contains_key(&key);
            let bucket = st
                .buckets
                .entry(key)
                .or_insert_with(|| Bucket { first_at, entries: Vec::new() });
            bucket.entries.push(entry);
            // Only a new bucket (fresh deadline) or a full one changes
            // what the batcher would do; appending to a non-full bucket
            // needs no wakeup.
            created || bucket.entries.len() >= self.policy.max_batch
        };
        if wake {
            self.cv.notify_all();
        }
        Ok(())
    }

    /// No more enqueuers exist: wake the batcher so it flushes every
    /// remaining bucket (ignoring the coalescing wait) and exits.
    fn close(&self) {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner).closed = true;
        self.cv.notify_all();
    }

    /// Block until a bucket is dispatchable — full (`>= max_batch`),
    /// expired (oldest entry waited `wait_us`), or anything at all once
    /// closed — and take the whole bucket. Among ready buckets the
    /// weighted-fair credits pick a tier class (so a flood of Background
    /// buckets cannot starve Interactive ones), then the oldest bucket
    /// of that class dispatches (so no shape is starved by a hot one
    /// within a class). Returns `None` when closed and fully drained.
    fn next_batch(&self) -> Option<Vec<PendingGemm>> {
        let mut st = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        loop {
            let now = Instant::now();
            let mut eligible = [false; Priority::COUNT];
            let mut ready: Vec<(BucketKey, Instant, usize)> = Vec::new();
            for (&key, b) in &st.buckets {
                let dispatchable = st.closed
                    || b.entries.len() >= self.policy.max_batch
                    || now.duration_since(b.first_at) >= self.policy.wait();
                if dispatchable {
                    let class = b
                        .entries
                        .iter()
                        .map(|e| e.tier.index())
                        .min()
                        .unwrap_or(Priority::Background.index());
                    eligible[class] = true;
                    ready.push((key, b.first_at, class));
                }
            }
            if !ready.is_empty() {
                let class = st.credits.pick(eligible);
                let chosen = class
                    .and_then(|c| {
                        ready.iter().filter(|r| r.2 == c).min_by_key(|r| r.1).map(|r| r.0)
                    })
                    // Defensive: the credits disagreed with the
                    // eligibility probe — fall back to oldest overall
                    // rather than stall the batcher.
                    .or_else(|| ready.iter().min_by_key(|r| r.1).map(|r| r.0));
                if let Some(key) = chosen {
                    if let Some(bucket) = st.buckets.remove(&key) {
                        st.pending -= bucket.entries.len();
                        return Some(bucket.entries);
                    }
                }
                // Impossible (`ready` came from this map under the same
                // lock), but re-evaluate rather than panic.
                continue;
            }
            if st.closed {
                return None; // closed and drained
            }
            // Sleep until the nearest deadline; with nothing parked,
            // park outright (enqueue/close always notify).
            let deadline = st
                .buckets
                .values()
                .map(|b| (b.first_at + self.policy.wait()).saturating_duration_since(now))
                .min();
            st = match deadline {
                Some(timeout) => {
                    let (guard, _) = self
                        .cv
                        .wait_timeout(st, timeout.max(Duration::from_micros(1)))
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    guard
                }
                None => self.cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner),
            };
        }
    }
}

/// The batcher thread: owns its own coordinator (engine + metrics) on
/// the shared pool, turns dispatchable buckets into fused
/// [`crate::gemm::GemmEngine::gemm_batch`] epochs, and answers every
/// member's reply channel. Entries whose deadline expired while parked
/// are dropped with [`DlaError::Timeout`]; a panicking fused dispatch is
/// isolated with `catch_unwind` and every member answered with
/// [`DlaError::Internal`] (the batcher thread survives). Returns its
/// metrics at exit for the shutdown merge.
fn batcher_loop(
    queue: Arc<BatchQueue>,
    arch: Arch,
    mode: ConfigMode,
    pool: Option<Arc<WorkerPool>>,
    tiers: Arc<TierCounters>,
    profile: Option<Arc<PerfProfile>>,
) -> Metrics {
    let mut co = Coordinator::new(arch, mode);
    if let Some(pool) = pool {
        co = co.with_pool(pool);
    }
    if let Some(p) = profile {
        // The batcher's per-member config selection reads the blended
        // scores, but fused epochs are not timed (one epoch serves many
        // members; per-member attribution is unknowable) and never
        // explored (a bucket may carry Interactive-tier members).
        co = co.with_calibration(p);
        co.engine.set_explore_allowed(false);
    }
    while let Some(batch) = queue.next_batch() {
        // Deadline-expired entries get a Timeout, not a late answer.
        let now = Instant::now();
        let (mut entries, expired): (Vec<_>, Vec<_>) =
            batch.into_iter().partition(|e| e.deadline.is_none_or(|d| now < d));
        for e in expired {
            let fm = co.metrics.faults_mut();
            fm.timeouts += 1;
            fm.expired_in_queue += 1;
            tiers.add_failed(e.tier);
            let _ = e.reply.send(Err(DlaError::Timeout {
                waited_ms: e.enqueued.elapsed().as_millis() as u64,
            }));
        }
        if entries.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        let waits: Vec<u64> =
            entries.iter().map(|e| t0.duration_since(e.enqueued).as_nanos() as u64).collect();
        // A bucket's key carries the dtype, so a batch is uniformly f64
        // or uniformly f32 — one fused dispatch per precision path.
        let f32_batch = entries.first().is_some_and(|e| matches!(e.req, DlaRequest::GemmF32 { .. }));
        let dispatch = if f32_batch {
            catch_unwind(AssertUnwindSafe(|| {
                let mut items: Vec<GemmBatchItem<'_, f32>> = entries
                    .iter_mut()
                    .map(|e| {
                        let DlaRequest::GemmF32 { alpha, a, b, beta, c } = &mut e.req else {
                            unreachable!("dtype-keyed buckets admit one precision");
                        };
                        GemmBatchItem {
                            alpha: *alpha,
                            a: a.view(),
                            b: b.view(),
                            beta: *beta,
                            c: c.view_mut(),
                        }
                    })
                    .collect();
                co.engine.gemm_batch_t::<f32>(&mut items)
            }))
        } else {
            catch_unwind(AssertUnwindSafe(|| {
                let mut items: Vec<GemmBatchItem<'_>> = entries
                    .iter_mut()
                    .map(|e| {
                        let DlaRequest::Gemm { alpha, a, b, beta, c } = &mut e.req else {
                            unreachable!("dtype-keyed buckets admit one precision");
                        };
                        GemmBatchItem {
                            alpha: *alpha,
                            a: a.view(),
                            b: b.view(),
                            beta: *beta,
                            c: c.view_mut(),
                        }
                    })
                    .collect();
                co.engine.gemm_batch(&mut items)
            }))
        };
        let configs = match dispatch {
            Ok(configs) => configs,
            Err(payload) => {
                // Isolate the panic: answer every member, keep serving.
                co.metrics.faults_mut().worker_panics += 1;
                let err = DlaError::Internal {
                    reason: format!("fused dispatch panicked: {}", panic_reason(&*payload)),
                };
                for e in entries {
                    tiers.add_failed(e.tier);
                    let _ = e.reply.send(Err(err.clone()));
                }
                continue;
            }
        };
        let dt = t0.elapsed().as_secs_f64();
        co.metrics.record_batch_dispatch(entries.len(), &waits);
        for (e, cfg) in entries.into_iter().zip(configs) {
            let flops = e.req.flops();
            let kind = e.req.kind();
            // Every member of the fused epoch observed the epoch's wall
            // time as its service latency.
            co.metrics.record(kind, dt, flops);
            tiers.add_completed(e.tier);
            let resp = match e.req {
                DlaRequest::Gemm { c, .. } => DlaResponse::Matrix {
                    result: c,
                    config: Some(cfg.to_string()),
                    seconds: dt,
                },
                DlaRequest::GemmF32 { c, .. } => DlaResponse::MatrixF32 {
                    result: c,
                    config: Some(cfg.to_string()),
                    seconds: dt,
                },
                _ => unreachable!("only GEMM requests are admitted"),
            };
            let _ = e.reply.send(Ok(resp));
        }
        co.snapshot_pool_stats();
    }
    co.metrics
}

/// Per-worker serving context: the degraded-mode ladder, the overload
/// detector feed, and the per-tier outcome ledger, bundled so the worker
/// loop and its solo-fallback paths serve through one code path.
struct ServeCtx {
    /// The degraded fallback coordinator: pool-less, created lazily on
    /// the first degraded request.
    serial: Option<Coordinator>,
    /// Shared count-down of requests still to serve degraded.
    degraded: Arc<AtomicU64>,
    /// Window a fresh panic arms (before any brownout widening).
    window: u64,
    detector: Arc<OverloadDetector>,
    tiers: Arc<TierCounters>,
    arch: Arch,
    mode: ConfigMode,
    /// The server's resolved ABFT policy: the degraded serial fallback
    /// coordinator must verify exactly like the pooled path it replaces.
    verify: VerifyPolicy,
}

impl ServeCtx {
    /// Serve one request with panic isolation and the degraded-mode
    /// ladder: while the shared degraded budget is armed, the request
    /// runs on the serial coordinator (bitwise identical, reduced
    /// throughput); a handler panic is caught, answered with
    /// [`DlaError::Internal`], and arms the budget — widened by the
    /// brownout factor when the overload detector is at its severe
    /// level. `analytic_us` is the cost model's estimate for this
    /// request (0 when the model has none); the detector's cost EWMA
    /// observes `max(analytic, measured)` so a debug build or a degraded
    /// machine raises the overload baseline instead of tripping it.
    fn serve_one(
        &mut self,
        co: &mut Coordinator,
        tier: Priority,
        analytic_us: u64,
        req: DlaRequest,
        reply: &mpsc::Sender<Result<DlaResponse, DlaError>>,
    ) {
        let use_degraded = self.degraded.load(Ordering::Relaxed) > 0
            && self
                .degraded
                .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| n.checked_sub(1))
                .is_ok();
        let t0 = Instant::now();
        let outcome = {
            let arch = &self.arch;
            let mode = &self.mode;
            let verify = self.verify;
            let target: &mut Coordinator = if use_degraded {
                self.serial.get_or_insert_with(|| {
                    Coordinator::new(arch.clone(), mode.clone()).with_verify(verify)
                })
            } else {
                co
            };
            catch_unwind(AssertUnwindSafe(|| target.handle(req)))
        };
        match outcome {
            Ok(resp) => {
                if use_degraded {
                    co.metrics.faults_mut().degraded_requests += 1;
                }
                let measured_us = t0.elapsed().as_micros() as u64;
                self.detector.observe_cost_us(measured_us.max(analytic_us));
                if resp.is_ok() {
                    self.tiers.add_completed(tier);
                } else {
                    self.tiers.add_failed(tier);
                }
                let _ = reply.send(resp);
            }
            Err(payload) => {
                // By the time the panic reached us the pool already ran
                // its epoch recovery (poison cleared, workspaces reset)
                // — see runtime::pool. Isolate, arm the degraded window
                // (brownout-widened under severe overload), answer.
                co.metrics.faults_mut().worker_panics += 1;
                let window =
                    self.window.saturating_mul(self.detector.level().brownout_factor());
                self.degraded.fetch_max(window, Ordering::AcqRel);
                self.tiers.add_failed(tier);
                let _ = reply.send(Err(DlaError::Internal {
                    reason: format!("request handler panicked: {}", panic_reason(&*payload)),
                }));
            }
        }
    }
}

/// Submit-side fault counters (bumped on the caller's thread, where no
/// worker metrics object exists), merged into [`Metrics`] at shutdown.
#[derive(Default)]
struct SubmitCounters {
    invalid_inputs: AtomicU64,
    retries: AtomicU64,
    queue_full_rejections: AtomicU64,
    timeouts: AtomicU64,
    workers_lost: AtomicU64,
}

/// A non-blocking handle to a request submitted with
/// [`CoordinatorServer::submit_async`]: poll for completion, wait with
/// the server's deadline, or cancel still-queued work. Dropping the
/// handle abandons the result (the worker's reply send fails silently);
/// it does not cancel the job.
pub struct JobHandle {
    rx: mpsc::Receiver<Result<DlaResponse, DlaError>>,
    ctrl: Arc<HandleCtrl>,
    submitted: Instant,
    /// Absolute expiry mirroring the server deadline, bounding `wait`.
    deadline: Option<Instant>,
    /// Result buffered by `poll` / `wait_for` until the caller takes it.
    done: Option<Result<DlaResponse, DlaError>>,
    counters: Arc<SubmitCounters>,
}

impl JobHandle {
    /// Non-blocking: is the result ready? Once true, [`Self::wait`] and
    /// [`Self::wait_for`] return immediately (the result is buffered in
    /// the handle; polling never loses it).
    pub fn poll(&mut self) -> bool {
        if self.done.is_some() {
            return true;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.done = Some(r);
                true
            }
            Err(mpsc::TryRecvError::Empty) => false,
            Err(mpsc::TryRecvError::Disconnected) => {
                self.counters.workers_lost.fetch_add(1, Ordering::Relaxed);
                self.done = Some(Err(DlaError::WorkerLost {
                    reason: "worker dropped the reply channel".to_string(),
                }));
                true
            }
        }
    }

    /// Cancel the job if it is still queued. True when the cancellation
    /// won: the job will never start, and the result is
    /// [`DlaError::Cancelled`]. False when a worker already claimed (or
    /// finished) it — in-flight work runs to completion and its result
    /// stays available.
    pub fn cancel(&mut self) -> bool {
        if self.done.is_some() {
            return false;
        }
        self.ctrl.cancel()
    }

    /// Block up to `timeout` for the result. `Some` hands the result
    /// out (call once; the handle is spent for result delivery after
    /// that), `None` means the job is still running and the handle
    /// remains valid to keep polling or waiting.
    pub fn wait_for(&mut self, timeout: Duration) -> Option<Result<DlaResponse, DlaError>> {
        if let Some(r) = self.done.take() {
            return Some(r);
        }
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                self.counters.workers_lost.fetch_add(1, Ordering::Relaxed);
                Some(Err(DlaError::WorkerLost {
                    reason: "worker dropped the reply channel".to_string(),
                }))
            }
        }
    }

    /// Block for the result. With a server deadline armed the wait is
    /// bounded: a result that does not arrive in time yields
    /// [`DlaError::Timeout`] instead of blocking forever.
    pub fn wait(mut self) -> Result<DlaResponse, DlaError> {
        if let Some(r) = self.done.take() {
            return r;
        }
        match self.deadline {
            None => match self.rx.recv() {
                Ok(r) => r,
                Err(_) => {
                    self.counters.workers_lost.fetch_add(1, Ordering::Relaxed);
                    Err(DlaError::WorkerLost {
                        reason: "worker dropped the reply channel".to_string(),
                    })
                }
            },
            Some(d) => {
                let remaining = d.saturating_duration_since(Instant::now());
                match self.rx.recv_timeout(remaining) {
                    Ok(r) => r,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        self.counters.timeouts.fetch_add(1, Ordering::Relaxed);
                        Err(DlaError::Timeout {
                            waited_ms: self.submitted.elapsed().as_millis() as u64,
                        })
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        self.counters.workers_lost.fetch_add(1, Ordering::Relaxed);
                        Err(DlaError::WorkerLost {
                            reason: "worker dropped the reply channel".to_string(),
                        })
                    }
                }
            }
        }
    }
}

/// A running coordinator server.
pub struct CoordinatorServer {
    queue: Option<Arc<QosQueue<Job>>>,
    handles: Vec<thread::JoinHandle<Metrics>>,
    batch_queue: Option<Arc<BatchQueue>>,
    batch_handle: Option<thread::JoinHandle<Metrics>>,
    deadline: Option<Duration>,
    faults: Option<Arc<FaultState>>,
    counters: Arc<SubmitCounters>,
    /// Per-tier outcome ledger, shared with workers and the batcher.
    tiers: Arc<TierCounters>,
    detector: Arc<OverloadDetector>,
    /// Shared degraded-window count-down (for the shutdown gauge).
    degraded: Arc<AtomicU64>,
    default_tier: Priority,
    /// splitmix64 state for backoff jitter (no RNG dependency; the
    /// constant seed is fine — jitter decorrelates concurrent
    /// submitters, it does not need to be unpredictable).
    jitter_seed: AtomicU64,
    /// The shared measurement store (calibrated servers only), kept for
    /// the `DLA_PROFILE` save at shutdown and for test introspection.
    profile: Option<Arc<PerfProfile>>,
    /// Where to persist the store at shutdown (`DLA_PROFILE`, read once
    /// at start and only on calibrated servers).
    profile_path: Option<String>,
}

impl CoordinatorServer {
    /// Start `cfg.workers` worker threads (plus, when `gemm_threads > 1`,
    /// one shared persistent GEMM pool spawned here, once; plus, with
    /// batching enabled, one batcher thread draining the admission
    /// queue).
    ///
    /// Fails **on the caller's thread** with [`DlaError::InvalidInput`]
    /// when the pinned lookahead policy is invalid for `gemm_threads` —
    /// otherwise the engine-level validation would fire inside every
    /// detached worker and the misconfiguration would only surface as
    /// dead request channels.
    pub fn start(cfg: ServerConfig) -> Result<Self, DlaError> {
        if let Some(la) = cfg.lookahead {
            if let Err(e) = la.validate(cfg.gemm_threads.max(1)) {
                return Err(DlaError::InvalidInput {
                    reason: format!("invalid lookahead policy for this server config: {e}"),
                });
            }
        }
        // Pinned plan/deadline win; un-pinned servers take the env
        // overrides (DLA_FAULTS / DLA_DEADLINE_MS).
        let faults = cfg
            .faults
            .clone()
            .map(|p| Arc::new(FaultState::new(p)))
            .or_else(FaultState::from_env);
        let deadline = cfg.deadline.or_else(deadline_from_env);
        // ABFT policy: pinned wins, then the DLA_VERIFY override, then
        // Off. This is the *only* place DLA_VERIFY is read — engines
        // never consult the environment themselves, so a stray env var
        // cannot silently change results outside the serving path.
        let verify = cfg.verify.or_else(VerifyPolicy::from_env).unwrap_or(VerifyPolicy::Off);
        // Calibration: pinned wins, then the DLA_CALIBRATE override,
        // then Off. One shared profile is the cross-worker measurement
        // memory: every worker engine and the batcher blend against it.
        // The degraded serial fallback coordinator deliberately stays
        // pure-analytic — a post-panic cooldown window is the wrong
        // place to learn from timings.
        let calibrate =
            cfg.calibration.or_else(CalibratePolicy::from_env).unwrap_or(CalibratePolicy::Off);
        let profile = calibrate.enabled().then(|| Arc::new(PerfProfile::new()));
        // DLA_PROFILE persistence: load once here (a missing or
        // malformed file warns and cold-starts), save at shutdown.
        // Read only when calibration is armed, so an off server never
        // touches the filesystem.
        let profile_path = profile
            .is_some()
            .then(|| std::env::var("DLA_PROFILE").ok())
            .flatten()
            .filter(|p| !p.trim().is_empty());
        if let (Some(p), Some(path)) = (&profile, &profile_path) {
            p.load_from_path(path);
        }
        // A pinned batching policy always wins (so BatchPolicy::disabled()
        // really disables); un-pinned servers take the env override. On a
        // 1-thread pool admission can never succeed (is_batchable needs a
        // team to waste), so no queue or batcher thread is created at all.
        // A verified server disables batching outright: the fused batch
        // driver is unverified by design, and every request must get the
        // checksum-verified solo path.
        let batching = cfg
            .batching
            .or_else(BatchPolicy::from_env)
            .filter(BatchPolicy::enabled)
            .filter(|_| cfg.gemm_threads >= 2)
            .filter(|_| !verify.enabled());
        let batch_queue =
            batching.map(|policy| Arc::new(BatchQueue::new(policy, cfg.queue_depth)));
        let degraded_window =
            cfg.degraded_window.or_else(degraded_window_from_env).unwrap_or(DEGRADED_WINDOW);
        let default_tier = cfg.default_priority.or_else(Priority::from_env).unwrap_or_default();
        let queue = Arc::new(QosQueue::<Job>::new(cfg.queue_depth));
        // The shared pool consults the same armed fault state as the
        // server, so `panic@R:E` shots land inside real pooled epochs.
        let gemm_pool = (cfg.gemm_threads > 1)
            .then(|| Arc::new(WorkerPool::with_fault_state(cfg.gemm_threads, faults.clone())));
        let gemm_threads = cfg.gemm_threads.max(1);
        let degraded = Arc::new(AtomicU64::new(0));
        let detector = Arc::new(OverloadDetector::new());
        let tiers = Arc::new(TierCounters::new());
        // Spawn-error cleanup: already-spawned workers block on the
        // queue; closing both queues unblocks them so they exit instead
        // of leaking when start() fails partway.
        let abort = |queue: &QosQueue<Job>, batch_queue: &Option<Arc<BatchQueue>>| {
            queue.close();
            if let Some(q) = batch_queue {
                q.close();
            }
        };
        let mut handles = Vec::new();
        for i in 0..cfg.workers {
            let queue = queue.clone();
            let arch = cfg.arch.clone();
            let mode = cfg.mode.clone();
            let pool = gemm_pool.clone();
            let lookahead = cfg.lookahead;
            let batch = batch_queue.clone();
            let faults = faults.clone();
            let profile = profile.clone();
            let mut ctx = ServeCtx {
                serial: None,
                degraded: degraded.clone(),
                window: degraded_window,
                detector: detector.clone(),
                tiers: tiers.clone(),
                arch: cfg.arch.clone(),
                mode: cfg.mode.clone(),
                verify,
            };
            let spawned = thread::Builder::new()
                .name(format!("dla-worker-{i}"))
                .spawn(move || {
                    let mut co = Coordinator::new(arch, mode).with_verify(verify);
                    if let Some(pool) = pool {
                        co = co.with_pool(pool);
                    }
                    if let Some(la) = lookahead {
                        co = co.with_lookahead(la);
                    }
                    if let Some(p) = &profile {
                        co = co.with_calibration(Arc::clone(p));
                    }
                    // Per-worker admission memo (scorer runs once per
                    // distinct shape, not once per request). Calibrated
                    // servers blend measured rates into the admission
                    // estimates too (same shared store).
                    let planner = {
                        let mut pl = BatchPlanner::new();
                        if let Some(p) = &profile {
                            pl.set_profile(Some(Arc::clone(p)));
                        }
                        pl
                    };
                    // pop() blocks (weighted-fair across tiers) and
                    // returns None only when the queue is closed and
                    // fully drained.
                    while let Some(job) = queue.pop() {
                        let Job { req, tier, submitted, deadline, reply, ctrl } = job;
                        // The true queue wait, observed before any
                        // injected stall (a stall models slow handling,
                        // not queueing).
                        ctx.detector.observe_wait_us(submitted.elapsed().as_micros() as u64);
                        // Guaranteed cancellation of still-queued work:
                        // claim before any execution; a lost claim means
                        // the caller cancelled while we held the job.
                        if let Some(c) = &ctrl {
                            if !c.claim() {
                                ctx.tiers.add_cancelled(tier);
                                let _ = reply.send(Err(DlaError::Cancelled));
                                continue;
                            }
                        }
                        if let Some(f) = &faults {
                            f.stall_request();
                        }
                        // Exploration trades one request's latency for
                        // information — never spend an Interactive
                        // request on it. Meaningless (and skipped)
                        // without a profile attached.
                        if profile.is_some() {
                            co.engine.set_explore_allowed(tier != Priority::Interactive);
                        }
                        // Deadline already blown in the queue: drop the
                        // request instead of serving it late.
                        if deadline.is_some_and(|d| Instant::now() >= d) {
                            let fm = co.metrics.faults_mut();
                            fm.timeouts += 1;
                            fm.expired_in_queue += 1;
                            ctx.tiers.add_failed(tier);
                            let _ = reply.send(Err(DlaError::Timeout {
                                waited_ms: submitted.elapsed().as_millis() as u64,
                            }));
                            continue;
                        }
                        // Admission: route model-judged-small,
                        // well-formed GEMMs (either precision) into
                        // the batcher; everything else
                        // (factorizations, large GEMMs,
                        // deadline-tight requests) keeps the solo
                        // path. The bucket key pairs dtype with shape
                        // so precisions never coalesce, and each
                        // precision is judged by its own config and
                        // rate model.
                        let consistent_key = req
                            .gemm_dtype()
                            .zip(req.gemm_dims())
                            .filter(|_| req.gemm_shape_consistent());
                        if let (Some(q), Some((dt, dims))) = (&batch, consistent_key) {
                            let gemm_cfg = match dt {
                                DType::F64 => co.engine.plan_config(dims),
                                DType::F32 => co.engine.plan_config_t::<f32>(dims),
                            };
                            let remaining =
                                deadline.map(|d| d.saturating_duration_since(Instant::now()));
                            let admit = q.policy.fits_deadline(remaining)
                                && planner.is_batchable_elem(
                                    &co.engine.arch,
                                    gemm_cfg,
                                    dims,
                                    gemm_threads,
                                    &q.policy,
                                    dt.size_bytes(),
                                );
                            if admit {
                                let entry = PendingGemm {
                                    req,
                                    tier,
                                    reply,
                                    enqueued: Instant::now(),
                                    deadline,
                                };
                                if let Err(e) = q.try_enqueue((dt, dims), entry) {
                                    // Queue at its backpressure bound
                                    // (or closed): serve solo.
                                    let analytic = planner.estimate_us_elem(
                                        &co.engine.arch,
                                        gemm_cfg,
                                        dims,
                                        dt.size_bytes(),
                                    );
                                    ctx.serve_one(&mut co, e.tier, analytic, e.req, &e.reply);
                                }
                                continue;
                            }
                        }
                        let analytic_us = match consistent_key {
                            Some((dt, dims)) => {
                                let gemm_cfg = match dt {
                                    DType::F64 => co.engine.plan_config(dims),
                                    DType::F32 => co.engine.plan_config_t::<f32>(dims),
                                };
                                planner.estimate_us_elem(
                                    &co.engine.arch,
                                    gemm_cfg,
                                    dims,
                                    dt.size_bytes(),
                                )
                            }
                            None => 0,
                        };
                        ctx.serve_one(&mut co, tier, analytic_us, req, &reply);
                    }
                    co.snapshot_pool_stats();
                    if let Some(s) = ctx.serial.take() {
                        co.metrics.merge(s.metrics);
                    }
                    co.metrics
                });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    abort(&queue, &batch_queue);
                    return Err(DlaError::Internal {
                        reason: format!("spawning server worker: {e}"),
                    });
                }
            }
        }
        let batch_handle = match batch_queue.as_ref() {
            None => None,
            Some(q) => {
                let bq = Arc::clone(q);
                let arch = cfg.arch.clone();
                let mode = cfg.mode.clone();
                let pool = gemm_pool.clone();
                let btiers = tiers.clone();
                let bprofile = profile.clone();
                match thread::Builder::new()
                    .name("dla-batcher".to_string())
                    .spawn(move || batcher_loop(bq, arch, mode, pool, btiers, bprofile))
                {
                    Ok(h) => Some(h),
                    Err(e) => {
                        abort(&queue, &batch_queue);
                        return Err(DlaError::Internal {
                            reason: format!("spawning batcher: {e}"),
                        });
                    }
                }
            }
        };
        let server = Self {
            queue: Some(queue),
            handles,
            batch_queue,
            batch_handle,
            deadline,
            faults,
            counters: Arc::new(SubmitCounters::default()),
            tiers,
            detector,
            degraded,
            default_tier,
            jitter_seed: AtomicU64::new(cfg.jitter_seed.unwrap_or(DEFAULT_JITTER_SEED)),
            profile,
            profile_path,
        };
        // The canned overload drill: inject the planned flood as
        // Background-tier requests through the real admission path
        // (validation, shedding, tier budget), with the replies
        // abandoned. Outcomes land in the per-tier ledger like any other
        // traffic, so the drill is observable and reconciles.
        if let Some(f) = &server.faults {
            for _ in 0..f.take_flood() {
                let _ = server.enqueue(DlaRequest::flood_probe(), Priority::Background, None);
            }
        }
        Ok(server)
    }

    /// The armed fault state, if any (chaos tests assert delivered-shot
    /// counters through this).
    pub fn fault_state(&self) -> Option<Arc<FaultState>> {
        self.faults.clone()
    }

    /// The shared measurement store, if calibration is armed (tests
    /// assert observation counts and store integrity through this).
    pub fn profile(&self) -> Option<Arc<PerfProfile>> {
        self.profile.clone()
    }

    /// splitmix64 step for backoff jitter.
    fn jitter(&self) -> u64 {
        let x = self
            .jitter_seed
            .fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed)
            .wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = x;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Jittered exponential backoff for admission retries: attempt `n`
    /// sleeps in `[base/2, base]` with `base = 100µs · 2ⁿ`, capped at
    /// 10 ms. Jitter decorrelates submitters hammering a full queue.
    fn backoff(&self, attempt: u32) -> Duration {
        const BASE_US: u64 = 100;
        const CAP_US: u64 = 10_000;
        let base = BASE_US.saturating_mul(1u64 << attempt.min(16)).min(CAP_US);
        Duration::from_micros(base / 2 + self.jitter() % (base / 2 + 1))
    }

    /// The admission core shared by every submit variant: validate,
    /// account the tier, consult the load shedder, then push into the
    /// weighted-fair queue under the tier's retry budget.
    fn enqueue(
        &self,
        req: DlaRequest,
        tier: Priority,
        ctrl: Option<Arc<HandleCtrl>>,
    ) -> Result<mpsc::Receiver<Result<DlaResponse, DlaError>>, DlaError> {
        if let Err(e) = req.validate() {
            self.counters.invalid_inputs.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }
        let queue = match &self.queue {
            Some(q) => q,
            None => {
                return Err(DlaError::Internal { reason: "server already shut down".to_string() })
            }
        };
        // Everything past validation is ledgered: submitted must equal
        // completed + failed + shed + rejected + cancelled at shutdown.
        self.tiers.add_submitted(tier);
        // Adaptive shedding: when measured queue delay runs far ahead
        // of the analytic cost baseline, refuse low-tier work up front
        // instead of queueing it to miss its deadline.
        if self.detector.sheds(tier) {
            self.tiers.add_shed(tier);
            return Err(DlaError::Overloaded {
                tier: tier.label(),
                queue_delay_us: self.detector.queue_delay_us(),
            });
        }
        let submitted = Instant::now();
        let deadline = self.deadline.map(|d| submitted + d);
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut job = Job { req, tier, submitted, deadline, reply: reply_tx, ctrl };
        let budget = tier.admission_attempts();
        let mut attempt: u32 = 0;
        loop {
            // An injected queue-full (chaos drill) consumes an attempt
            // exactly like a real full queue.
            let forced = self.faults.as_deref().is_some_and(FaultState::admission_queue_full);
            if !forced {
                match queue.try_push(tier, job) {
                    Ok(()) => return Ok(reply_rx),
                    Err(PushError::Full(j)) => job = j,
                    Err(PushError::Closed(_)) => {
                        self.counters.workers_lost.fetch_add(1, Ordering::Relaxed);
                        self.tiers.add_rejected(tier);
                        return Err(DlaError::WorkerLost {
                            reason: "request queue closed (no live workers)".to_string(),
                        });
                    }
                }
            }
            attempt += 1;
            self.counters.retries.fetch_add(1, Ordering::Relaxed);
            if attempt >= budget {
                self.counters.queue_full_rejections.fetch_add(1, Ordering::Relaxed);
                self.tiers.add_rejected(tier);
                return Err(DlaError::QueueFull { retries: attempt });
            }
            if deadline.is_some_and(|d| Instant::now() >= d) {
                self.counters.timeouts.fetch_add(1, Ordering::Relaxed);
                self.tiers.add_rejected(tier);
                return Err(DlaError::Timeout {
                    waited_ms: submitted.elapsed().as_millis() as u64,
                });
            }
            thread::sleep(self.backoff(attempt));
        }
    }

    /// Submit a request at the server's default tier; returns a
    /// receiver for the response.
    ///
    /// Fails fast with [`DlaError::InvalidInput`] on malformed requests
    /// (before consuming any queue capacity), sheds under overload with
    /// [`DlaError::Overloaded`] (low tiers first — Interactive is never
    /// shed), retries a full queue with bounded jittered backoff up to
    /// the tier's budget before giving up with [`DlaError::QueueFull`],
    /// and reports a closed queue as [`DlaError::WorkerLost`] (not
    /// retried — the request cannot be safely replayed once ownership
    /// moved). With a deadline armed, backoff never sleeps past the
    /// deadline ([`DlaError::Timeout`]).
    pub fn submit(
        &self,
        req: DlaRequest,
    ) -> Result<mpsc::Receiver<Result<DlaResponse, DlaError>>, DlaError> {
        self.submit_at(req, self.default_tier)
    }

    /// [`Self::submit`] at an explicit QoS tier.
    pub fn submit_at(
        &self,
        req: DlaRequest,
        tier: Priority,
    ) -> Result<mpsc::Receiver<Result<DlaResponse, DlaError>>, DlaError> {
        self.enqueue(req, tier, None)
    }

    /// Non-blocking submit at the server's default tier: returns a
    /// [`JobHandle`] that can be polled, waited on (deadline-bounded),
    /// or cancelled.
    pub fn submit_async(&self, req: DlaRequest) -> Result<JobHandle, DlaError> {
        self.submit_async_at(req, self.default_tier)
    }

    /// [`Self::submit_async`] at an explicit QoS tier.
    ///
    /// Admission errors (invalid input, shed, queue full) surface here
    /// synchronously; once a handle is returned the request is queued
    /// and [`JobHandle::cancel`] can still revoke it before a worker
    /// claims it.
    pub fn submit_async_at(&self, req: DlaRequest, tier: Priority) -> Result<JobHandle, DlaError> {
        let ctrl = Arc::new(HandleCtrl::new());
        let submitted = Instant::now();
        let rx = self.enqueue(req, tier, Some(ctrl.clone()))?;
        Ok(JobHandle {
            rx,
            ctrl,
            submitted,
            deadline: self.deadline.map(|d| submitted + d),
            done: None,
            counters: self.counters.clone(),
        })
    }

    /// The overload detector's current verdict (healthy, shedding
    /// Background, or shedding Batch-and-below).
    pub fn overload_level(&self) -> OverloadLevel {
        self.detector.level()
    }

    /// Submit and wait. With a deadline armed the wait is bounded: a
    /// response that does not arrive in time yields
    /// [`DlaError::Timeout`] instead of blocking forever on a stalled
    /// or dead worker.
    pub fn call(&self, req: DlaRequest) -> Result<DlaResponse, DlaError> {
        let submitted = Instant::now();
        let rx = self.submit(req)?;
        match self.deadline {
            None => match rx.recv() {
                Ok(resp) => resp,
                Err(_) => {
                    self.counters.workers_lost.fetch_add(1, Ordering::Relaxed);
                    Err(DlaError::WorkerLost {
                        reason: "worker dropped the reply channel".to_string(),
                    })
                }
            },
            Some(d) => {
                let remaining = (submitted + d).saturating_duration_since(Instant::now());
                match rx.recv_timeout(remaining) {
                    Ok(resp) => resp,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        self.counters.timeouts.fetch_add(1, Ordering::Relaxed);
                        Err(DlaError::Timeout {
                            waited_ms: submitted.elapsed().as_millis() as u64,
                        })
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        self.counters.workers_lost.fetch_add(1, Ordering::Relaxed);
                        Err(DlaError::WorkerLost {
                            reason: "worker dropped the reply channel".to_string(),
                        })
                    }
                }
            }
        }
    }

    /// Shut down and merge worker (and batcher) metrics.
    ///
    /// # Drain semantics
    ///
    /// Every request accepted by [`Self::submit`] is served before any
    /// thread is joined — nothing is dropped, in two stages:
    ///
    /// 1. **Queue drain.** Closing the weighted-fair queue makes each
    ///    worker's `pop` yield every already-queued request before
    ///    reporting closure, so workers finish (or route into the
    ///    batcher) all of them and only then exit; joining here cannot
    ///    strand queued work.
    /// 2. **Admission-queue drain.** Only after every worker has exited
    ///    (i.e. no enqueuer remains) is the batch queue closed; `close`
    ///    makes the batcher flush every pending bucket immediately —
    ///    ignoring the coalescing wait — answer the replies, and exit.
    ///
    /// Shutdown is poison-tolerant: a worker that died to an unhandled
    /// panic is counted in `workers_lost` (the survivors' metrics still
    /// merge) instead of propagating the panic to the caller.
    ///
    /// The returned metrics merge every worker's counters plus the
    /// batcher's (batched GEMM latencies, [`super::metrics::BatchMetrics`],
    /// the latest shared-pool idle snapshot, and the submit-side fault
    /// counters).
    pub fn shutdown(mut self) -> Metrics {
        if let Some(q) = self.queue.take() {
            q.close();
        }
        let mut all = Metrics::new();
        for h in self.handles.drain(..) {
            match h.join() {
                Ok(m) => all.merge(m),
                Err(_) => all.faults_mut().workers_lost += 1,
            }
        }
        if let Some(q) = self.batch_queue.take() {
            q.close();
        }
        if let Some(h) = self.batch_handle.take() {
            match h.join() {
                Ok(m) => all.merge(m),
                Err(_) => all.faults_mut().workers_lost += 1,
            }
        }
        let c = &self.counters;
        let f = all.faults_mut();
        f.invalid_inputs += c.invalid_inputs.load(Ordering::Relaxed);
        f.retries += c.retries.load(Ordering::Relaxed);
        f.queue_full_rejections += c.queue_full_rejections.load(Ordering::Relaxed);
        f.timeouts += c.timeouts.load(Ordering::Relaxed);
        f.workers_lost += c.workers_lost.load(Ordering::Relaxed);
        f.degraded_remaining += self.degraded.load(Ordering::Relaxed);
        *all.qos_mut() = self.tiers.snapshot();
        // Persist the measurement store for the next process (the
        // DLA_PROFILE round-trip). A write failure warns and is
        // otherwise ignored: persistence must never fail a shutdown.
        if let (Some(p), Some(path)) = (&self.profile, &self.profile_path) {
            if let Err(e) = p.save_to_path(path) {
                eprintln!("dla: failed to save DLA_PROFILE={path:?}: {e}");
            }
        }
        // Machine-readable counterpart of the summary table: one JSON
        // object on stdout, opt-in so interactive output stays clean.
        if std::env::var("DLA_METRICS_JSON").is_ok_and(|v| v.trim() == "1") {
            println!("{}", all.snapshot_json());
        }
        all
    }
}

impl Drop for CoordinatorServer {
    /// Dropping without [`Self::shutdown`] must not leak threads: close
    /// the request queue and the batcher's admission queue so workers
    /// and the batcher unblock and exit (releasing their `Arc` on the
    /// shared pool, whose own `Drop` then retires the team). Metrics
    /// are lost and the threads are detached, not joined — call
    /// `shutdown` for the orderly two-stage drain. After `shutdown`
    /// every field is already `None` and this is a no-op.
    fn drop(&mut self) {
        if let Some(q) = self.queue.take() {
            q.close();
        }
        if let Some(q) = self.batch_queue.take() {
            q.close();
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::util::{MatrixF64, Pcg64};

    fn gemm_req(rng: &mut Pcg64, m: usize, n: usize, k: usize) -> DlaRequest {
        DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::random(m, k, rng),
            b: MatrixF64::random(k, n, rng),
            beta: 0.0,
            c: MatrixF64::zeros(m, n),
        }
    }

    #[test]
    fn server_round_trip() {
        let server =
            CoordinatorServer::start(ServerConfig::new(host_xeon(), ConfigMode::Refined)).unwrap();
        let mut rng = Pcg64::seed(9);
        let resp = server.call(gemm_req(&mut rng, 30, 20, 10)).unwrap();
        assert!(resp.seconds() >= 0.0);
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
        assert!(metrics.fault_stats().is_clean(), "healthy run must report no faults");
    }

    #[test]
    fn server_multiple_workers_process_all() {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined).with_workers(3),
        )
        .unwrap();
        let mut rng = Pcg64::seed(10);
        let mut pending = Vec::new();
        for i in 0..12 {
            let sz = 16 + (i % 4) * 8;
            pending.push(server.submit(gemm_req(&mut rng, sz, sz, 8)).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 12);
    }

    #[test]
    fn server_shares_one_gemm_pool_across_workers() {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3),
        )
        .unwrap();
        let mut rng = Pcg64::seed(11);
        let mut pending = Vec::new();
        for _ in 0..6 {
            pending.push(server.submit(gemm_req(&mut rng, 48, 40, 16)).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 6);
    }

    #[test]
    fn server_reports_pool_idle_stats_and_serves_lookahead_lu() {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_lookahead(Lookahead { depth: 1, panel_workers: 1 }),
        )
        .unwrap();
        let mut rng = Pcg64::seed(12);
        let a = MatrixF64::random_diag_dominant(64, &mut rng);
        let resp = server.call(DlaRequest::LuFactor { a: a.clone(), block: 16 }).unwrap();
        let DlaResponse::Lu { factors, .. } = resp else { panic!() };
        assert!(factors.reconstruction_error(&a) < 1e-10);
        let metrics = server.shutdown();
        let pool = metrics.pool_stats().expect("pooled server must surface pool stats");
        assert!(pool.jobs > 0, "LU trailing updates must have run pooled jobs: {pool:?}");
        assert!(metrics.summary().contains("gemm pool:"));
    }

    #[test]
    fn server_rejects_invalid_lookahead_up_front() {
        // The typed error must come back on the caller's thread from
        // start(), not surface inside detached workers.
        let err = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_lookahead(Lookahead { depth: 1, panel_workers: 3 }),
        )
        .err()
        .expect("invalid lookahead must fail start()");
        let DlaError::InvalidInput { reason } = err else {
            panic!("expected InvalidInput, got {err:?}")
        };
        assert!(reason.contains("lookahead"), "{reason}");
    }

    #[test]
    fn server_serves_both_dtypes_on_one_shared_pool() {
        use crate::util::MatrixF32;
        // One 3-thread pool; f64 GEMM + f32 GEMM + mixed-precision solve
        // all flow through it (the mixed solve factors in f32 on the
        // pooled pipeline and refines with f64 pooled GEMMs).
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3),
        )
        .unwrap();
        let mut rng = Pcg64::seed(31);
        let g64 = server.submit(gemm_req(&mut rng, 64, 48, 16)).unwrap();
        let a32 = MatrixF32::random(64, 24, &mut rng);
        let b32 = MatrixF32::random(24, 48, &mut rng);
        let g32 = server
            .submit(DlaRequest::GemmF32 {
                alpha: 1.0,
                a: a32.clone(),
                b: b32.clone(),
                beta: 0.0,
                c: MatrixF32::zeros(64, 48),
            })
            .unwrap();
        let a = crate::util::MatrixF64::random_diag_dominant(96, &mut rng);
        let x_true = crate::util::MatrixF64::random(96, 1, &mut rng);
        let mut rhs = crate::util::MatrixF64::zeros(96, 1);
        crate::gemm::gemm_reference(1.0, a.view(), x_true.view(), 0.0, &mut rhs.view_mut());
        let mx = server.submit(DlaRequest::MixedSolve { a, rhs, block: 24 }).unwrap();
        g64.recv().unwrap().unwrap();
        let DlaResponse::MatrixF32 { result, .. } = g32.recv().unwrap().unwrap() else {
            panic!()
        };
        let mut expect = MatrixF32::zeros(64, 48);
        crate::gemm::gemm_reference(1.0f32, a32.view(), b32.view(), 0.0f32, &mut expect.view_mut());
        assert!(result.max_abs_diff(&expect) < 1e-3);
        let DlaResponse::MixedSolve { x, fell_back, residual, .. } = mx.recv().unwrap().unwrap()
        else {
            panic!()
        };
        assert!(!fell_back);
        assert!(residual <= 1e-10, "{residual}");
        assert!(x.max_abs_diff(&x_true) < 1e-8);
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
        assert_eq!(metrics.count("gemm_f32"), 1);
        assert_eq!(metrics.count("mixed_lu"), 1);
        assert_eq!(metrics.refine_stats().solves, 1);
        let pool = metrics.pool_stats().expect("pooled server must surface pool stats");
        assert!(pool.jobs > 0, "both dtypes must have dispatched pooled jobs: {pool:?}");
        assert!(metrics.summary().contains("mixed precision:"));
    }

    #[test]
    fn server_propagates_errors() {
        let server =
            CoordinatorServer::start(ServerConfig::new(host_xeon(), ConfigMode::Refined)).unwrap();
        let resp = server.call(DlaRequest::LuFactor { a: MatrixF64::zeros(6, 6), block: 2 });
        assert_eq!(resp.err(), Some(DlaError::Singular { pivot: 0 }));
        server.shutdown();
    }

    #[test]
    fn submit_rejects_invalid_input_before_queueing() {
        let server =
            CoordinatorServer::start(ServerConfig::new(host_xeon(), ConfigMode::Refined)).unwrap();
        let mut a = MatrixF64::zeros(4, 4);
        a.set(1, 1, f64::NAN);
        let err = server
            .submit(DlaRequest::LuFactor { a, block: 2 })
            .expect_err("NaN operand must be rejected at admission");
        assert!(matches!(err, DlaError::InvalidInput { .. }), "{err:?}");
        assert!(!err.is_transient(), "invalid input is not retryable");
        let metrics = server.shutdown();
        assert_eq!(metrics.count("lu"), 0, "the request must never reach a worker");
        assert_eq!(metrics.fault_stats().invalid_inputs, 1);
    }

    #[test]
    fn deadline_expires_a_stalled_request() {
        // Worker stalls 300 ms on every dequeued request; the caller's
        // deadline is 40 ms. call() must give up at the deadline with a
        // typed Timeout, not block on the stalled worker.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_deadline(Duration::from_millis(40))
                .with_faults(FaultPlan::parse("stall:300").unwrap()),
        )
        .unwrap();
        let mut rng = Pcg64::seed(41);
        let t0 = Instant::now();
        let err = server.call(gemm_req(&mut rng, 16, 16, 8)).err().expect("must time out");
        assert!(matches!(err, DlaError::Timeout { .. }), "{err:?}");
        assert!(err.is_transient());
        assert!(t0.elapsed() < Duration::from_millis(250), "call must not wait out the stall");
        let metrics = server.shutdown();
        let f = metrics.fault_stats();
        // Caller-side timeout always fires; the worker may additionally
        // have dropped it as expired-in-queue after the stall.
        assert!(f.timeouts >= 1, "{f:?}");
    }

    #[test]
    fn forced_queue_full_retries_then_rejects() {
        // A burst longer than the retry budget: submit must retry with
        // backoff, then reject with QueueFull carrying the retry count.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_faults(FaultPlan::parse("queuefull:100").unwrap()),
        )
        .unwrap();
        let mut rng = Pcg64::seed(42);
        let err = server.submit(gemm_req(&mut rng, 16, 16, 8)).expect_err("must reject");
        assert_eq!(err, DlaError::QueueFull { retries: MAX_ADMISSION_ATTEMPTS });
        assert!(err.is_transient());
        // A burst shorter than the budget is absorbed by the retries.
        let short = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_faults(FaultPlan::parse("queuefull:3").unwrap()),
        )
        .unwrap();
        let rx = short.submit(gemm_req(&mut rng, 16, 16, 8)).expect("retries must absorb burst");
        rx.recv().unwrap().unwrap();
        let metrics = short.shutdown();
        let f = metrics.fault_stats();
        assert_eq!(f.retries, 3, "{f:?}");
        assert_eq!(f.queue_full_rejections, 0, "{f:?}");
        server.shutdown();
    }

    #[test]
    fn batching_server_coalesces_small_gemms() {
        // A long wait + a small full-trigger: the only way requests get
        // served promptly is the full-bucket dispatch, so coalescing is
        // deterministic (the remainder flushes at shutdown).
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3)
                .with_batching(
                    BatchPolicy::default().with_max_batch(4).with_wait_us(5_000_000).admit_all(),
                ),
        )
        .unwrap();
        let mut rng = Pcg64::seed(21);
        let mut pending = Vec::new();
        for _ in 0..8 {
            pending.push(server.submit(gemm_req(&mut rng, 24, 24, 12)).unwrap());
        }
        // Shutdown drains everything (including a not-yet-full remainder
        // bucket), so the replies are all available afterwards.
        let metrics = server.shutdown();
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(metrics.count("gemm"), 8);
        let b = metrics.batch_stats();
        assert_eq!(b.total_requests(), 8, "every small gemm goes through the batcher: {b:?}");
        assert!(b.batches >= 1, "the full trigger must have fired: {b:?}");
        // The first full-bucket dispatch alone coalesces max_batch
        // requests.
        assert!(b.coalesced_requests >= 4, "{b:?}");
        assert_eq!(b.queue_wait_ns.count, 8);
        assert!(metrics.summary().contains("batching:"));
    }

    #[test]
    fn batching_server_coalesces_f32_gemms_in_their_own_buckets() {
        use crate::util::MatrixF32;
        // Same shape in both precisions with admit_all: the dtype-keyed
        // buckets must coalesce each precision separately — four f32
        // requests fill one f32 bucket (full-trigger dispatch through
        // gemm_batch_t::<f32>) while the four same-shape f64 requests
        // fill their own. A shape-only key would mix them and the fused
        // dispatch would reinterpret operands of the wrong width.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3)
                .with_batching(
                    BatchPolicy::default().with_max_batch(4).with_wait_us(5_000_000).admit_all(),
                ),
        )
        .unwrap();
        let mut rng = Pcg64::seed(77);
        let mut f32_jobs = Vec::new();
        let mut f64_jobs = Vec::new();
        for _ in 0..4 {
            let a = MatrixF32::random(24, 12, &mut rng);
            let b = MatrixF32::random(12, 24, &mut rng);
            let mut expect = MatrixF32::zeros(24, 24);
            crate::gemm::gemm_reference(1.0f32, a.view(), b.view(), 0.0f32, &mut expect.view_mut());
            let rx = server
                .submit(DlaRequest::GemmF32 {
                    alpha: 1.0,
                    a,
                    b,
                    beta: 0.0,
                    c: MatrixF32::zeros(24, 24),
                })
                .unwrap();
            f32_jobs.push((rx, expect));
            f64_jobs.push(server.submit(gemm_req(&mut rng, 24, 24, 12)).unwrap());
        }
        let metrics = server.shutdown();
        for (rx, expect) in f32_jobs {
            let DlaResponse::MatrixF32 { result, .. } = rx.recv().unwrap().unwrap() else {
                panic!("f32 request must answer with an f32 matrix")
            };
            assert!(result.max_abs_diff(&expect) < 1e-3);
        }
        for rx in f64_jobs {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(metrics.count("gemm_f32"), 4);
        assert_eq!(metrics.count("gemm"), 4);
        let b = metrics.batch_stats();
        assert_eq!(b.total_requests(), 8, "both precisions go through the batcher: {b:?}");
        assert!(b.batches >= 2, "each precision dispatches as its own bucket: {b:?}");
    }

    #[test]
    fn batch_queue_bounds_pending_entries() {
        // The admission queue must preserve the server's backpressure: at
        // the bound, try_enqueue hands the entry back (the worker serves
        // it solo); draining a bucket frees capacity.
        let q = BatchQueue::new(BatchPolicy::default().with_max_batch(2), 2);
        let dims = (DType::F64, GemmDims::new(8, 8, 8));
        let entry = || PendingGemm {
            req: DlaRequest::Gemm {
                alpha: 1.0,
                a: MatrixF64::zeros(8, 8),
                b: MatrixF64::zeros(8, 8),
                beta: 0.0,
                c: MatrixF64::zeros(8, 8),
            },
            tier: Priority::Interactive,
            reply: mpsc::channel().0,
            enqueued: Instant::now(),
            deadline: None,
        };
        assert!(q.try_enqueue(dims, entry()).is_ok());
        assert!(q.try_enqueue(dims, entry()).is_ok());
        assert!(q.try_enqueue(dims, entry()).is_err(), "bound must reject the third entry");
        // The full bucket is dispatchable; draining frees capacity.
        let batch = q.next_batch().expect("full bucket ready");
        assert_eq!(batch.len(), 2);
        assert!(q.try_enqueue(dims, entry()).is_ok());
    }

    #[test]
    fn tight_deadlines_bypass_the_batcher() {
        // An hour-long coalescing window with a 100 ms deadline: a
        // batched request would park past its deadline, so the
        // fits_deadline gate must route it to the solo path, where it
        // is served promptly.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_deadline(Duration::from_millis(30_000))
                .with_batching(BatchPolicy::default().with_wait_us(3_600_000_000).admit_all()),
        )
        .unwrap();
        let mut rng = Pcg64::seed(43);
        server.call(gemm_req(&mut rng, 24, 24, 12)).unwrap();
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
        assert_eq!(
            metrics.batch_stats().total_requests(),
            0,
            "deadline-tight gemm must not park in the batcher"
        );
    }

    #[test]
    fn pinned_disabled_batching_beats_env() {
        // BatchPolicy::disabled() must force the solo path even when the
        // CI matrix exports DLA_BATCH=1.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_batching(BatchPolicy::disabled()),
        )
        .unwrap();
        let mut rng = Pcg64::seed(22);
        server.call(gemm_req(&mut rng, 24, 24, 12)).unwrap();
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
        assert_eq!(metrics.batch_stats().total_requests(), 0);
    }

    #[test]
    fn factorizations_bypass_the_batcher() {
        // With an hour-long coalescing window, a batched request would
        // visibly hang — factorizations must come back via the solo path
        // immediately, composing with lookahead on the shared pool.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_batching(BatchPolicy::default().with_wait_us(3_600_000_000).admit_all()),
        )
        .unwrap();
        let mut rng = Pcg64::seed(23);
        let a = MatrixF64::random_diag_dominant(48, &mut rng);
        let resp = server.call(DlaRequest::LuFactor { a: a.clone(), block: 16 }).unwrap();
        let DlaResponse::Lu { factors, .. } = resp else { panic!() };
        assert!(factors.reconstruction_error(&a) < 1e-10);
        let metrics = server.shutdown();
        assert_eq!(metrics.count("lu"), 1);
        assert_eq!(metrics.batch_stats().total_requests(), 0, "LU must not touch the batcher");
    }

    #[test]
    fn interactive_budget_is_the_legacy_admission_cap() {
        // The chaos suite pins `queuefull:100` and asserts
        // `QueueFull { retries: MAX_ADMISSION_ATTEMPTS }` on the default
        // (Interactive) tier — the tier budget must stay in lockstep.
        assert_eq!(Priority::Interactive.admission_attempts(), MAX_ADMISSION_ATTEMPTS);
    }

    #[test]
    fn next_batch_prefers_the_higher_tier_bucket() {
        // Two ready buckets: the Background one parked first (older),
        // the Interactive one second. Weighted-fair dispatch must open
        // its cycle with the Interactive-class bucket, not the oldest.
        let q = BatchQueue::new(BatchPolicy::default().with_max_batch(8), 16);
        let entry = |tier| PendingGemm {
            req: DlaRequest::Gemm {
                alpha: 1.0,
                a: MatrixF64::zeros(8, 8),
                b: MatrixF64::zeros(8, 8),
                beta: 0.0,
                c: MatrixF64::zeros(8, 8),
            },
            tier,
            reply: mpsc::channel().0,
            enqueued: Instant::now(),
            deadline: None,
        };
        let bg_dims = (DType::F64, GemmDims::new(8, 8, 8));
        let it_dims = (DType::F64, GemmDims::new(8, 8, 16));
        assert!(q.try_enqueue(bg_dims, entry(Priority::Background)).is_ok());
        thread::sleep(Duration::from_millis(2));
        assert!(q.try_enqueue(it_dims, entry(Priority::Interactive)).is_ok());
        // Closing makes both buckets immediately dispatchable.
        q.close();
        let first = q.next_batch().expect("closed queue flushes");
        assert_eq!(first[0].tier, Priority::Interactive, "interactive bucket dispatches first");
        let second = q.next_batch().expect("background bucket still pending");
        assert_eq!(second[0].tier, Priority::Background);
        assert!(q.next_batch().is_none(), "closed and drained");
    }

    #[test]
    fn submit_at_background_round_trips() {
        let server =
            CoordinatorServer::start(ServerConfig::new(host_xeon(), ConfigMode::Refined)).unwrap();
        let mut rng = Pcg64::seed(44);
        let rx = server.submit_at(gemm_req(&mut rng, 24, 24, 8), Priority::Background).unwrap();
        rx.recv().unwrap().unwrap();
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
        let qos = metrics.qos_stats();
        assert_eq!(qos.submitted[Priority::Background.index()], 1);
        assert_eq!(qos.completed[Priority::Background.index()], 1);
        assert!(qos.reconciles(), "{qos:?}");
    }

    #[test]
    fn async_handle_polls_then_waits() {
        let server =
            CoordinatorServer::start(ServerConfig::new(host_xeon(), ConfigMode::Refined)).unwrap();
        let mut rng = Pcg64::seed(45);
        let mut handle = server.submit_async(gemm_req(&mut rng, 24, 24, 8)).unwrap();
        // Poll until ready (bounded), then wait() returns the buffered
        // response without blocking.
        let t0 = Instant::now();
        while !handle.poll() {
            assert!(t0.elapsed() < Duration::from_secs(30), "gemm must complete");
            thread::sleep(Duration::from_millis(1));
        }
        handle.wait().unwrap();
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
        assert!(metrics.qos_stats().reconciles());
    }

    #[test]
    fn degraded_window_env_parser_accepts_positive_integers_only() {
        // Pure parser check (no env mutation): the config override path.
        let cfg = ServerConfig::new(host_xeon(), ConfigMode::Refined).with_degraded_window(3);
        assert_eq!(cfg.degraded_window, Some(3));
        let clamped = ServerConfig::new(host_xeon(), ConfigMode::Refined).with_degraded_window(0);
        assert_eq!(clamped.degraded_window, Some(1), "window 0 would disable recovery");
    }
}
