//! A worker-thread request loop around the [`super::Coordinator`]:
//! requests flow through a bounded channel (backpressure), each worker
//! owns its engine (and thus its workspace pool and config-selection memo
//! cache), and per-worker metrics are merged at shutdown.
//!
//! With [`ServerConfig::with_gemm_threads`] the server provisions **one**
//! persistent GEMM worker pool at startup and shares it across every
//! request worker's engine: heavy requests get intra-request parallelism,
//! the team is spawned exactly once for the lifetime of the server (pool
//! `run`s from different workers serialize on the pool's leader lock, so
//! the machine is never oversubscribed), and no request ever pays thread
//! creation cost.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use crate::arch::Arch;
use crate::gemm::{ConfigMode, Lookahead};
use crate::runtime::pool::WorkerPool;

use super::metrics::Metrics;
use super::requests::{DlaRequest, DlaResponse};
use super::Coordinator;

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub arch: Arch,
    pub mode: ConfigMode,
    /// Channel capacity (backpressure bound).
    pub queue_depth: usize,
    /// Width of the shared intra-request GEMM pool (1 = sequential GEMMs).
    pub gemm_threads: usize,
    /// Lookahead policy for blocked factorization requests; `None` keeps
    /// the engine heuristic (and the `DLA_LOOKAHEAD` env override).
    pub lookahead: Option<Lookahead>,
}

impl ServerConfig {
    pub fn new(arch: Arch, mode: ConfigMode) -> Self {
        Self { workers: 1, arch, mode, queue_depth: 64, gemm_threads: 1, lookahead: None }
    }

    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Share one persistent `n`-thread GEMM pool across all workers.
    pub fn with_gemm_threads(mut self, n: usize) -> Self {
        self.gemm_threads = n.max(1);
        self
    }

    /// Pin the lookahead policy every worker engine serves with.
    pub fn with_lookahead(mut self, la: Lookahead) -> Self {
        self.lookahead = Some(la);
        self
    }
}

type Job = (DlaRequest, mpsc::Sender<anyhow::Result<DlaResponse>>);

/// A running coordinator server.
pub struct CoordinatorServer {
    tx: Option<mpsc::SyncSender<Job>>,
    handles: Vec<thread::JoinHandle<Metrics>>,
}

impl CoordinatorServer {
    /// Start `cfg.workers` worker threads (plus, when `gemm_threads > 1`,
    /// one shared persistent GEMM pool spawned here, once).
    ///
    /// Panics **on the caller's thread** when the pinned lookahead
    /// policy is invalid for `gemm_threads` — otherwise the engine-level
    /// validation would fire inside every detached worker and the
    /// misconfiguration would only surface as dead request channels.
    pub fn start(cfg: ServerConfig) -> Self {
        if let Some(la) = cfg.lookahead {
            if let Err(e) = la.validate(cfg.gemm_threads.max(1)) {
                panic!("invalid lookahead policy for this server config: {e}");
            }
        }
        let (tx, rx) = mpsc::sync_channel::<Job>(cfg.queue_depth);
        let rx = std::sync::Arc::new(std::sync::Mutex::new(rx));
        let gemm_pool =
            (cfg.gemm_threads > 1).then(|| Arc::new(WorkerPool::new(cfg.gemm_threads)));
        let mut handles = Vec::new();
        for _ in 0..cfg.workers {
            let rx = rx.clone();
            let arch = cfg.arch.clone();
            let mode = cfg.mode.clone();
            let pool = gemm_pool.clone();
            let lookahead = cfg.lookahead;
            handles.push(thread::spawn(move || {
                let mut co = Coordinator::new(arch, mode);
                if let Some(pool) = pool {
                    co = co.with_pool(pool);
                }
                if let Some(la) = lookahead {
                    co = co.with_lookahead(la);
                }
                loop {
                    // Hold the lock only while receiving.
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok((req, reply)) => {
                            let resp = co.handle(req);
                            let _ = reply.send(resp);
                        }
                        Err(_) => break, // channel closed: drain done
                    }
                }
                co.metrics
            }));
        }
        Self { tx: Some(tx), handles }
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: DlaRequest) -> mpsc::Receiver<anyhow::Result<DlaResponse>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("server already shut down")
            .send((req, reply_tx))
            .expect("worker pool gone");
        reply_rx
    }

    /// Submit and wait.
    pub fn call(&self, req: DlaRequest) -> anyhow::Result<DlaResponse> {
        self.submit(req).recv().expect("worker dropped reply channel")
    }

    /// Shut down and merge worker metrics.
    pub fn shutdown(mut self) -> Metrics {
        drop(self.tx.take());
        let mut all = Metrics::new();
        for h in self.handles.drain(..) {
            all.merge(h.join().expect("worker panicked"));
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::util::{MatrixF64, Pcg64};

    fn gemm_req(rng: &mut Pcg64, m: usize, n: usize, k: usize) -> DlaRequest {
        DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::random(m, k, rng),
            b: MatrixF64::random(k, n, rng),
            beta: 0.0,
            c: MatrixF64::zeros(m, n),
        }
    }

    #[test]
    fn server_round_trip() {
        let server = CoordinatorServer::start(ServerConfig::new(host_xeon(), ConfigMode::Refined));
        let mut rng = Pcg64::seed(9);
        let resp = server.call(gemm_req(&mut rng, 30, 20, 10)).unwrap();
        assert!(resp.seconds() >= 0.0);
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
    }

    #[test]
    fn server_multiple_workers_process_all() {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined).with_workers(3),
        );
        let mut rng = Pcg64::seed(10);
        let mut pending = Vec::new();
        for i in 0..12 {
            let sz = 16 + (i % 4) * 8;
            pending.push(server.submit(gemm_req(&mut rng, sz, sz, 8)));
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 12);
    }

    #[test]
    fn server_shares_one_gemm_pool_across_workers() {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3),
        );
        let mut rng = Pcg64::seed(11);
        let mut pending = Vec::new();
        for _ in 0..6 {
            pending.push(server.submit(gemm_req(&mut rng, 48, 40, 16)));
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 6);
    }

    #[test]
    fn server_reports_pool_idle_stats_and_serves_lookahead_lu() {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_lookahead(Lookahead { depth: 1, panel_workers: 1 }),
        );
        let mut rng = Pcg64::seed(12);
        let a = MatrixF64::random_diag_dominant(64, &mut rng);
        let resp = server.call(DlaRequest::LuFactor { a: a.clone(), block: 16 }).unwrap();
        let DlaResponse::Lu { factors, .. } = resp else { panic!() };
        assert!(factors.reconstruction_error(&a) < 1e-10);
        let metrics = server.shutdown();
        let pool = metrics.pool_stats().expect("pooled server must surface pool stats");
        assert!(pool.jobs > 0, "LU trailing updates must have run pooled jobs: {pool:?}");
        assert!(metrics.summary().contains("gemm pool:"));
    }

    #[test]
    #[should_panic(expected = "invalid lookahead policy for this server config")]
    fn server_rejects_invalid_lookahead_up_front() {
        // The panic must fire on the caller's thread at start(), not
        // inside detached workers.
        let _ = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_lookahead(Lookahead { depth: 1, panel_workers: 3 }),
        );
    }

    #[test]
    fn server_propagates_errors() {
        let server = CoordinatorServer::start(ServerConfig::new(host_xeon(), ConfigMode::Refined));
        let resp = server.call(DlaRequest::LuFactor { a: MatrixF64::zeros(6, 6), block: 2 });
        assert!(resp.is_err());
        server.shutdown();
    }
}
