//! A worker-thread request loop around the [`super::Coordinator`]:
//! requests flow through a bounded channel (backpressure), each worker
//! owns its engine (and thus its workspace pool and config-selection memo
//! cache), and per-worker metrics are merged at shutdown.
//!
//! With [`ServerConfig::with_gemm_threads`] the server provisions **one**
//! persistent GEMM worker pool at startup and shares it across every
//! request worker's engine: heavy requests get intra-request parallelism,
//! the team is spawned exactly once for the lifetime of the server (pool
//! `run`s from different workers serialize on the pool's leader lock, so
//! the machine is never oversubscribed), and no request ever pays thread
//! creation cost.
//!
//! # The batched request scheduler
//!
//! With batching enabled ([`ServerConfig::with_batching`], or the
//! `DLA_BATCH` / `DLA_BATCH_WAIT_US` environment knobs on un-pinned
//! servers), small GEMM requests no longer each run one whole pool
//! dispatch under the leader lock. Instead the request path becomes:
//!
//! 1. **Admission.** A worker pulls a request from the channel as usual,
//!    but routes it into the admission queue when the
//!    [`crate::model::batchplan`] cost model says a full-team dispatch
//!    would waste the machine (estimated single-core time below the
//!    policy threshold, or a G4 grain too small to feed the team). The
//!    queue **buckets by problem shape**; factorizations and large GEMMs
//!    bypass the batcher entirely and keep the existing (lookahead)
//!    path — the two schedulers compose on one shared pool. Parked
//!    entries are bounded by `queue_depth` (preserving the channel's
//!    backpressure); at the bound, requests are served solo. Requests
//!    whose deadline is tighter than the coalescing window also bypass
//!    the batcher ([`BatchPolicy::fits_deadline`]) — coalescing trades
//!    latency for throughput, and a deadline caps that trade.
//! 2. **Coalescing.** A dedicated batcher thread sleeps until a bucket
//!    is dispatchable: it reached `max_batch` entries, its oldest entry
//!    has waited `wait_us`, or the server is shutting down.
//! 3. **Fused dispatch.** The bucket is executed as one (or, above the
//!    team width, a few chunked) fused pool epoch(s) via
//!    [`crate::gemm::GemmEngine::gemm_batch`]: the team is partitioned
//!    across the batch members by the same cost model, every member
//!    keeps its own memoized per-shape configuration, and each result is
//!    **bitwise identical** to what a solo dispatch would have produced
//!    (asserted by `tests/batching.rs`).
//!
//! Per-batch observability (dispatch-size histogram, coalesced-vs-solo
//! counts, per-request queue wait) is recorded in
//! [`super::metrics::BatchMetrics`] and merged into the server metrics
//! at shutdown. A response served from a fused dispatch reports the
//! epoch's wall time as its `seconds` (the latency that request
//! actually observed).
//!
//! # Fault tolerance
//!
//! The serving path degrades instead of dying (see the failure-model
//! section of `lapack/README.md` for the full ladder):
//!
//! - **Admission validation.** [`Self::submit`] rejects malformed
//!   requests (NaN/Inf operands, shape mismatches) with
//!   [`DlaError::InvalidInput`] *before* they consume queue capacity.
//! - **Deadlines.** [`ServerConfig::with_deadline`] (or
//!   `DLA_DEADLINE_MS`) bounds every request end to end: expired
//!   requests are dropped at dequeue (and in the batcher) with
//!   [`DlaError::Timeout`], and [`Self::call`] stops waiting at the
//!   deadline instead of blocking forever on a stalled worker.
//! - **Backpressure retries.** A full channel is transient:
//!   [`Self::submit`] retries with bounded, jittered exponential backoff
//!   before giving up with [`DlaError::QueueFull`].
//! - **Panic isolation + degraded mode.** A request whose handler
//!   panics is answered with [`DlaError::Internal`] (the worker thread
//!   survives via `catch_unwind`; the shared pool has already recovered
//!   its epoch — see `runtime::pool`). The next
//!   [`DEGRADED_WINDOW`] requests are then served by a pool-less serial
//!   coordinator — bitwise identical results at reduced throughput —
//!   before the worker resumes trusting the pooled path.
//! - **Poison-tolerant shutdown.** [`Self::shutdown`] never unwraps a
//!   `join`: a dead worker is counted as `workers_lost` and the
//!   surviving workers' metrics are still merged.
//!
//! Every fault is counted in [`super::metrics::FaultMetrics`] (the
//! `resilience:` summary line). Fault *injection* for drills and the
//! chaos suite is armed with [`ServerConfig::with_faults`] or the
//! `DLA_FAULTS` environment knob (see `runtime::faults`).
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::arch::Arch;
use crate::gemm::{ConfigMode, GemmBatchItem, Lookahead};
use crate::model::batchplan::{BatchPlanner, BatchPolicy};
use crate::model::GemmDims;
use crate::runtime::faults::{FaultPlan, FaultState};
use crate::runtime::pool::WorkerPool;
use crate::util::error::{panic_reason, DlaError};

use super::metrics::Metrics;
use super::requests::{DlaRequest, DlaResponse};
use super::Coordinator;

/// How many requests a worker serves on the pool-less serial fallback
/// path after isolating a handler panic, before trusting the pooled
/// path again. The serial blocked path is bitwise identical to the
/// pooled one (asserted by `tests/chaos.rs`), so correctness is never
/// degraded — only throughput.
pub const DEGRADED_WINDOW: u64 = 8;

/// Admission attempts before a persistently full queue turns into
/// [`DlaError::QueueFull`] (initial try + retries with backoff).
const MAX_ADMISSION_ATTEMPTS: u32 = 8;

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub arch: Arch,
    pub mode: ConfigMode,
    /// Channel capacity (backpressure bound).
    pub queue_depth: usize,
    /// Width of the shared intra-request GEMM pool (1 = sequential GEMMs).
    pub gemm_threads: usize,
    /// Lookahead policy for blocked factorization requests; `None` keeps
    /// the engine heuristic (and the `DLA_LOOKAHEAD` env override).
    pub lookahead: Option<Lookahead>,
    /// Batching policy for small GEMM requests; `None` defers to the
    /// `DLA_BATCH` environment override (pin
    /// [`crate::model::BatchPolicy::disabled`] to force batching off).
    pub batching: Option<BatchPolicy>,
    /// End-to-end deadline applied to every request; `None` defers to
    /// the `DLA_DEADLINE_MS` environment override (unset = no deadline).
    pub deadline: Option<Duration>,
    /// Fault-injection plan for drills and the chaos suite; `None`
    /// defers to the `DLA_FAULTS` environment override (unset = hooks
    /// un-armed, zero cost).
    pub faults: Option<FaultPlan>,
}

impl ServerConfig {
    pub fn new(arch: Arch, mode: ConfigMode) -> Self {
        Self {
            workers: 1,
            arch,
            mode,
            queue_depth: 64,
            gemm_threads: 1,
            lookahead: None,
            batching: None,
            deadline: None,
            faults: None,
        }
    }

    pub fn with_workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Share one persistent `n`-thread GEMM pool across all workers.
    pub fn with_gemm_threads(mut self, n: usize) -> Self {
        self.gemm_threads = n.max(1);
        self
    }

    /// Pin the lookahead policy every worker engine serves with.
    pub fn with_lookahead(mut self, la: Lookahead) -> Self {
        self.lookahead = Some(la);
        self
    }

    /// Pin the batching policy (see the module docs). A pinned policy
    /// always wins over the `DLA_BATCH` environment override.
    pub fn with_batching(mut self, policy: BatchPolicy) -> Self {
        self.batching = Some(policy);
        self
    }

    /// Bound every request end to end: expired requests are answered
    /// with [`DlaError::Timeout`] instead of being served late, and
    /// [`CoordinatorServer::call`] stops waiting at the deadline. A
    /// pinned deadline wins over the `DLA_DEADLINE_MS` override.
    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Arm a fault-injection plan (chaos drills; see `runtime::faults`).
    /// A pinned plan wins over the `DLA_FAULTS` override.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }
}

/// The `DLA_DEADLINE_MS` override: a positive integer arms a per-request
/// deadline on servers that did not pin one; unset / unparseable / `0`
/// means no deadline (a typo must fail toward "no new failure mode").
fn deadline_from_env() -> Option<Duration> {
    std::env::var("DLA_DEADLINE_MS")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .map(Duration::from_millis)
}

/// One request in flight between `submit` and a worker.
struct Job {
    req: DlaRequest,
    /// When `submit` accepted the request (the latency/timeout anchor).
    submitted: Instant,
    /// Absolute expiry, if the server has a deadline.
    deadline: Option<Instant>,
    reply: mpsc::Sender<Result<DlaResponse, DlaError>>,
}

/// One admitted request parked in the admission queue (always a
/// `DlaRequest::Gemm` — admission guarantees it), with everything needed
/// to execute and answer it.
struct PendingGemm {
    req: DlaRequest,
    reply: mpsc::Sender<Result<DlaResponse, DlaError>>,
    enqueued: Instant,
    deadline: Option<Instant>,
}

struct Bucket {
    /// Enqueue time of the oldest entry (the dispatch deadline anchor).
    first_at: Instant,
    entries: Vec<PendingGemm>,
}

#[derive(Default)]
struct QueueState {
    buckets: HashMap<GemmDims, Bucket>,
    /// Entries across all buckets (the backpressure bound).
    pending: usize,
    closed: bool,
}

/// The admission queue of the batch scheduler: workers push admitted
/// small GEMMs in (bucketed by shape), the batcher thread pulls whole
/// buckets out when they are worth dispatching. Total parked entries are
/// bounded by `max_pending` so the admission queue cannot defeat the
/// bounded request channel's backpressure — an over-limit request is
/// handed back to the worker, which serves it solo.
struct BatchQueue {
    policy: BatchPolicy,
    max_pending: usize,
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl BatchQueue {
    fn new(policy: BatchPolicy, max_pending: usize) -> Self {
        Self {
            policy,
            max_pending: max_pending.max(policy.max_batch),
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
        }
    }

    /// Park an admitted request, or hand it back when the queue is at
    /// its backpressure bound or already closed (`Err` = caller must
    /// serve it solo). The closed check matters when the server is
    /// dropped without `shutdown()`: the batcher may already be gone,
    /// and a parked entry would never be answered.
    fn try_enqueue(&self, dims: GemmDims, entry: PendingGemm) -> Result<(), PendingGemm> {
        let wake = {
            let mut st = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if st.closed || st.pending >= self.max_pending {
                return Err(entry);
            }
            st.pending += 1;
            let first_at = entry.enqueued;
            let created = !st.buckets.contains_key(&dims);
            let bucket = st
                .buckets
                .entry(dims)
                .or_insert_with(|| Bucket { first_at, entries: Vec::new() });
            bucket.entries.push(entry);
            // Only a new bucket (fresh deadline) or a full one changes
            // what the batcher would do; appending to a non-full bucket
            // needs no wakeup.
            created || bucket.entries.len() >= self.policy.max_batch
        };
        if wake {
            self.cv.notify_all();
        }
        Ok(())
    }

    /// No more enqueuers exist: wake the batcher so it flushes every
    /// remaining bucket (ignoring the coalescing wait) and exits.
    fn close(&self) {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner).closed = true;
        self.cv.notify_all();
    }

    /// Block until a bucket is dispatchable — full (`>= max_batch`),
    /// expired (oldest entry waited `wait_us`), or anything at all once
    /// closed — and take the whole bucket. Oldest bucket first, so no
    /// shape can be starved by a hot one. Returns `None` when closed and
    /// fully drained.
    fn next_batch(&self) -> Option<Vec<PendingGemm>> {
        let mut st = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        loop {
            let now = Instant::now();
            let ready = st
                .buckets
                .iter()
                .filter(|(_, b)| {
                    st.closed
                        || b.entries.len() >= self.policy.max_batch
                        || now.duration_since(b.first_at) >= self.policy.wait()
                })
                .min_by_key(|(_, b)| b.first_at)
                .map(|(&dims, _)| dims);
            if let Some(dims) = ready {
                match st.buckets.remove(&dims) {
                    Some(bucket) => {
                        st.pending -= bucket.entries.len();
                        return Some(bucket.entries);
                    }
                    // Impossible (`ready` came from this map under the
                    // same lock), but re-evaluate rather than panic.
                    None => continue,
                }
            }
            if st.closed {
                return None; // closed and drained
            }
            // Sleep until the nearest deadline; with nothing parked,
            // park outright (enqueue/close always notify).
            let deadline = st
                .buckets
                .values()
                .map(|b| (b.first_at + self.policy.wait()).saturating_duration_since(now))
                .min();
            st = match deadline {
                Some(timeout) => {
                    let (guard, _) = self
                        .cv
                        .wait_timeout(st, timeout.max(Duration::from_micros(1)))
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    guard
                }
                None => self.cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner),
            };
        }
    }
}

/// The batcher thread: owns its own coordinator (engine + metrics) on
/// the shared pool, turns dispatchable buckets into fused
/// [`crate::gemm::GemmEngine::gemm_batch`] epochs, and answers every
/// member's reply channel. Entries whose deadline expired while parked
/// are dropped with [`DlaError::Timeout`]; a panicking fused dispatch is
/// isolated with `catch_unwind` and every member answered with
/// [`DlaError::Internal`] (the batcher thread survives). Returns its
/// metrics at exit for the shutdown merge.
fn batcher_loop(
    queue: Arc<BatchQueue>,
    arch: Arch,
    mode: ConfigMode,
    pool: Option<Arc<WorkerPool>>,
) -> Metrics {
    let mut co = Coordinator::new(arch, mode);
    if let Some(pool) = pool {
        co = co.with_pool(pool);
    }
    while let Some(batch) = queue.next_batch() {
        // Deadline-expired entries get a Timeout, not a late answer.
        let now = Instant::now();
        let (mut entries, expired): (Vec<_>, Vec<_>) =
            batch.into_iter().partition(|e| e.deadline.is_none_or(|d| now < d));
        for e in expired {
            let fm = co.metrics.faults_mut();
            fm.timeouts += 1;
            fm.expired_in_queue += 1;
            let _ = e.reply.send(Err(DlaError::Timeout {
                waited_ms: e.enqueued.elapsed().as_millis() as u64,
            }));
        }
        if entries.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        let waits: Vec<u64> =
            entries.iter().map(|e| t0.duration_since(e.enqueued).as_nanos() as u64).collect();
        let dispatch = catch_unwind(AssertUnwindSafe(|| {
            let mut items: Vec<GemmBatchItem<'_>> = entries
                .iter_mut()
                .map(|e| {
                    let DlaRequest::Gemm { alpha, a, b, beta, c } = &mut e.req else {
                        unreachable!("only Gemm requests are admitted");
                    };
                    GemmBatchItem {
                        alpha: *alpha,
                        a: a.view(),
                        b: b.view(),
                        beta: *beta,
                        c: c.view_mut(),
                    }
                })
                .collect();
            co.engine.gemm_batch(&mut items)
        }));
        let configs = match dispatch {
            Ok(configs) => configs,
            Err(payload) => {
                // Isolate the panic: answer every member, keep serving.
                co.metrics.faults_mut().worker_panics += 1;
                let err = DlaError::Internal {
                    reason: format!("fused dispatch panicked: {}", panic_reason(&*payload)),
                };
                for e in entries {
                    let _ = e.reply.send(Err(err.clone()));
                }
                continue;
            }
        };
        let dt = t0.elapsed().as_secs_f64();
        co.metrics.record_batch_dispatch(entries.len(), &waits);
        for (e, cfg) in entries.into_iter().zip(configs) {
            let flops = e.req.flops();
            let DlaRequest::Gemm { c, .. } = e.req else {
                unreachable!("only Gemm requests are admitted");
            };
            // Every member of the fused epoch observed the epoch's wall
            // time as its service latency.
            co.metrics.record("gemm", dt, flops);
            let _ = e.reply.send(Ok(DlaResponse::Matrix {
                result: c,
                config: Some(cfg.to_string()),
                seconds: dt,
            }));
        }
        co.snapshot_pool_stats();
    }
    co.metrics
}

/// Serve one request on a worker thread with panic isolation and the
/// degraded-mode ladder: while the shared degraded budget is armed, the
/// request runs on a lazily created pool-less serial coordinator
/// (bitwise identical, reduced throughput); a handler panic is caught,
/// answered with [`DlaError::Internal`], and arms the budget.
fn serve_one(
    co: &mut Coordinator,
    serial: &mut Option<Coordinator>,
    degraded: &AtomicU64,
    arch: &Arch,
    mode: &ConfigMode,
    req: DlaRequest,
    reply: &mpsc::Sender<Result<DlaResponse, DlaError>>,
) {
    let use_degraded = degraded.load(Ordering::Relaxed) > 0
        && degraded
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| n.checked_sub(1))
            .is_ok();
    let outcome = {
        let target: &mut Coordinator = if use_degraded {
            serial.get_or_insert_with(|| Coordinator::new(arch.clone(), mode.clone()))
        } else {
            co
        };
        catch_unwind(AssertUnwindSafe(|| target.handle(req)))
    };
    match outcome {
        Ok(resp) => {
            if use_degraded {
                co.metrics.faults_mut().degraded_requests += 1;
            }
            let _ = reply.send(resp);
        }
        Err(payload) => {
            // By the time the panic reached us the pool already ran its
            // epoch recovery (poison cleared, workspaces reset) — see
            // runtime::pool. Isolate, arm the degraded window, answer.
            co.metrics.faults_mut().worker_panics += 1;
            degraded.fetch_max(DEGRADED_WINDOW, Ordering::AcqRel);
            let _ = reply.send(Err(DlaError::Internal {
                reason: format!("request handler panicked: {}", panic_reason(&*payload)),
            }));
        }
    }
}

/// Submit-side fault counters (bumped on the caller's thread, where no
/// worker metrics object exists), merged into [`Metrics`] at shutdown.
#[derive(Default)]
struct SubmitCounters {
    invalid_inputs: AtomicU64,
    retries: AtomicU64,
    queue_full_rejections: AtomicU64,
    timeouts: AtomicU64,
    workers_lost: AtomicU64,
}

/// A running coordinator server.
pub struct CoordinatorServer {
    tx: Option<mpsc::SyncSender<Job>>,
    handles: Vec<thread::JoinHandle<Metrics>>,
    batch_queue: Option<Arc<BatchQueue>>,
    batch_handle: Option<thread::JoinHandle<Metrics>>,
    deadline: Option<Duration>,
    faults: Option<Arc<FaultState>>,
    counters: Arc<SubmitCounters>,
    /// splitmix64 state for backoff jitter (no RNG dependency; the
    /// constant seed is fine — jitter decorrelates concurrent
    /// submitters, it does not need to be unpredictable).
    jitter_seed: AtomicU64,
}

impl CoordinatorServer {
    /// Start `cfg.workers` worker threads (plus, when `gemm_threads > 1`,
    /// one shared persistent GEMM pool spawned here, once; plus, with
    /// batching enabled, one batcher thread draining the admission
    /// queue).
    ///
    /// Fails **on the caller's thread** with [`DlaError::InvalidInput`]
    /// when the pinned lookahead policy is invalid for `gemm_threads` —
    /// otherwise the engine-level validation would fire inside every
    /// detached worker and the misconfiguration would only surface as
    /// dead request channels.
    pub fn start(cfg: ServerConfig) -> Result<Self, DlaError> {
        if let Some(la) = cfg.lookahead {
            if let Err(e) = la.validate(cfg.gemm_threads.max(1)) {
                return Err(DlaError::InvalidInput {
                    reason: format!("invalid lookahead policy for this server config: {e}"),
                });
            }
        }
        // Pinned plan/deadline win; un-pinned servers take the env
        // overrides (DLA_FAULTS / DLA_DEADLINE_MS).
        let faults = cfg
            .faults
            .clone()
            .map(|p| Arc::new(FaultState::new(p)))
            .or_else(FaultState::from_env);
        let deadline = cfg.deadline.or_else(deadline_from_env);
        // A pinned batching policy always wins (so BatchPolicy::disabled()
        // really disables); un-pinned servers take the env override. On a
        // 1-thread pool admission can never succeed (is_batchable needs a
        // team to waste), so no queue or batcher thread is created at all.
        let batching = cfg
            .batching
            .or_else(BatchPolicy::from_env)
            .filter(BatchPolicy::enabled)
            .filter(|_| cfg.gemm_threads >= 2);
        let batch_queue =
            batching.map(|policy| Arc::new(BatchQueue::new(policy, cfg.queue_depth)));
        let (tx, rx) = mpsc::sync_channel::<Job>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        // The shared pool consults the same armed fault state as the
        // server, so `panic@R:E` shots land inside real pooled epochs.
        let gemm_pool = (cfg.gemm_threads > 1)
            .then(|| Arc::new(WorkerPool::with_fault_state(cfg.gemm_threads, faults.clone())));
        let gemm_threads = cfg.gemm_threads.max(1);
        let degraded = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for i in 0..cfg.workers {
            let rx = rx.clone();
            let arch = cfg.arch.clone();
            let mode = cfg.mode.clone();
            let pool = gemm_pool.clone();
            let lookahead = cfg.lookahead;
            let queue = batch_queue.clone();
            let faults = faults.clone();
            let degraded = degraded.clone();
            let handle = thread::Builder::new()
                .name(format!("dla-worker-{i}"))
                .spawn(move || {
                    let mut co = Coordinator::new(arch.clone(), mode.clone());
                    if let Some(pool) = pool {
                        co = co.with_pool(pool);
                    }
                    if let Some(la) = lookahead {
                        co = co.with_lookahead(la);
                    }
                    // The degraded fallback coordinator: pool-less,
                    // created lazily on the first degraded request.
                    let mut serial: Option<Coordinator> = None;
                    // Per-worker admission memo (scorer runs once per
                    // distinct shape, not once per request).
                    let planner = BatchPlanner::new();
                    loop {
                        // Hold the lock only while receiving; a
                        // poisoned lock (a sibling died mid-recv) must
                        // not take this worker down with it.
                        let job = {
                            rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner).recv()
                        };
                        let Job { req, submitted, deadline, reply } = match job {
                            Ok(j) => j,
                            Err(_) => break, // channel closed: drain done
                        };
                        if let Some(f) = &faults {
                            f.stall_request();
                        }
                        // Deadline already blown in the queue: drop the
                        // request instead of serving it late.
                        if deadline.is_some_and(|d| Instant::now() >= d) {
                            let fm = co.metrics.faults_mut();
                            fm.timeouts += 1;
                            fm.expired_in_queue += 1;
                            let _ = reply.send(Err(DlaError::Timeout {
                                waited_ms: submitted.elapsed().as_millis() as u64,
                            }));
                            continue;
                        }
                        // Admission: route model-judged-small,
                        // well-formed GEMMs into the batcher;
                        // everything else (factorizations, large
                        // GEMMs, deadline-tight requests) keeps the
                        // solo path.
                        if let Some(q) = &queue {
                            if let Some(dims) = req.gemm_dims() {
                                let remaining = deadline
                                    .map(|d| d.saturating_duration_since(Instant::now()));
                                let admit = req.gemm_shape_consistent()
                                    && q.policy.fits_deadline(remaining)
                                    && planner.is_batchable(
                                        &co.engine.arch,
                                        co.engine.plan_config(dims),
                                        dims,
                                        gemm_threads,
                                        &q.policy,
                                    );
                                if admit {
                                    let entry = PendingGemm {
                                        req,
                                        reply,
                                        enqueued: Instant::now(),
                                        deadline,
                                    };
                                    if let Err(e) = q.try_enqueue(dims, entry) {
                                        // Queue at its backpressure
                                        // bound (or closed): serve solo.
                                        serve_one(
                                            &mut co, &mut serial, &degraded, &arch, &mode,
                                            e.req, &e.reply,
                                        );
                                    }
                                    continue;
                                }
                            }
                        }
                        serve_one(&mut co, &mut serial, &degraded, &arch, &mode, req, &reply);
                    }
                    co.snapshot_pool_stats();
                    if let Some(s) = serial {
                        co.metrics.merge(s.metrics);
                    }
                    co.metrics
                })
                .map_err(|e| DlaError::Internal {
                    reason: format!("spawning server worker: {e}"),
                })?;
            handles.push(handle);
        }
        let batch_handle = match batch_queue.as_ref() {
            None => None,
            Some(q) => {
                let queue = Arc::clone(q);
                let arch = cfg.arch.clone();
                let mode = cfg.mode.clone();
                let pool = gemm_pool.clone();
                Some(
                    thread::Builder::new()
                        .name("dla-batcher".to_string())
                        .spawn(move || batcher_loop(queue, arch, mode, pool))
                        .map_err(|e| DlaError::Internal {
                            reason: format!("spawning batcher: {e}"),
                        })?,
                )
            }
        };
        Ok(Self {
            tx: Some(tx),
            handles,
            batch_queue,
            batch_handle,
            deadline,
            faults,
            counters: Arc::new(SubmitCounters::default()),
            jitter_seed: AtomicU64::new(0x243F_6A88_85A3_08D3),
        })
    }

    /// The armed fault state, if any (chaos tests assert delivered-shot
    /// counters through this).
    pub fn fault_state(&self) -> Option<Arc<FaultState>> {
        self.faults.clone()
    }

    /// splitmix64 step for backoff jitter.
    fn jitter(&self) -> u64 {
        let x = self
            .jitter_seed
            .fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed)
            .wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = x;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Jittered exponential backoff for admission retries: attempt `n`
    /// sleeps in `[base/2, base]` with `base = 100µs · 2ⁿ`, capped at
    /// 10 ms. Jitter decorrelates submitters hammering a full queue.
    fn backoff(&self, attempt: u32) -> Duration {
        const BASE_US: u64 = 100;
        const CAP_US: u64 = 10_000;
        let base = BASE_US.saturating_mul(1u64 << attempt.min(16)).min(CAP_US);
        Duration::from_micros(base / 2 + self.jitter() % (base / 2 + 1))
    }

    /// Submit a request; returns a receiver for the response.
    ///
    /// Fails fast with [`DlaError::InvalidInput`] on malformed requests
    /// (before consuming any queue capacity), retries a full queue with
    /// bounded jittered backoff before giving up with
    /// [`DlaError::QueueFull`], and reports a dead worker side as
    /// [`DlaError::WorkerLost`] (not retried — the request cannot be
    /// safely replayed once ownership moved). With a deadline armed,
    /// backoff never sleeps past the deadline ([`DlaError::Timeout`]).
    pub fn submit(
        &self,
        req: DlaRequest,
    ) -> Result<mpsc::Receiver<Result<DlaResponse, DlaError>>, DlaError> {
        if let Err(e) = req.validate() {
            self.counters.invalid_inputs.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }
        let tx = match &self.tx {
            Some(tx) => tx,
            None => {
                return Err(DlaError::Internal { reason: "server already shut down".to_string() })
            }
        };
        let submitted = Instant::now();
        let deadline = self.deadline.map(|d| submitted + d);
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut job = Job { req, submitted, deadline, reply: reply_tx };
        let mut attempt: u32 = 0;
        loop {
            // An injected queue-full (chaos drill) consumes an attempt
            // exactly like a real full channel.
            let forced = self.faults.as_deref().is_some_and(FaultState::admission_queue_full);
            if !forced {
                match tx.try_send(job) {
                    Ok(()) => return Ok(reply_rx),
                    Err(mpsc::TrySendError::Full(j)) => job = j,
                    Err(mpsc::TrySendError::Disconnected(_)) => {
                        self.counters.workers_lost.fetch_add(1, Ordering::Relaxed);
                        return Err(DlaError::WorkerLost {
                            reason: "request channel disconnected (no live workers)".to_string(),
                        });
                    }
                }
            }
            attempt += 1;
            self.counters.retries.fetch_add(1, Ordering::Relaxed);
            if attempt >= MAX_ADMISSION_ATTEMPTS {
                self.counters.queue_full_rejections.fetch_add(1, Ordering::Relaxed);
                return Err(DlaError::QueueFull { retries: attempt });
            }
            if deadline.is_some_and(|d| Instant::now() >= d) {
                self.counters.timeouts.fetch_add(1, Ordering::Relaxed);
                return Err(DlaError::Timeout {
                    waited_ms: submitted.elapsed().as_millis() as u64,
                });
            }
            thread::sleep(self.backoff(attempt));
        }
    }

    /// Submit and wait. With a deadline armed the wait is bounded: a
    /// response that does not arrive in time yields
    /// [`DlaError::Timeout`] instead of blocking forever on a stalled
    /// or dead worker.
    pub fn call(&self, req: DlaRequest) -> Result<DlaResponse, DlaError> {
        let submitted = Instant::now();
        let rx = self.submit(req)?;
        match self.deadline {
            None => match rx.recv() {
                Ok(resp) => resp,
                Err(_) => {
                    self.counters.workers_lost.fetch_add(1, Ordering::Relaxed);
                    Err(DlaError::WorkerLost {
                        reason: "worker dropped the reply channel".to_string(),
                    })
                }
            },
            Some(d) => {
                let remaining = (submitted + d).saturating_duration_since(Instant::now());
                match rx.recv_timeout(remaining) {
                    Ok(resp) => resp,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        self.counters.timeouts.fetch_add(1, Ordering::Relaxed);
                        Err(DlaError::Timeout {
                            waited_ms: submitted.elapsed().as_millis() as u64,
                        })
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        self.counters.workers_lost.fetch_add(1, Ordering::Relaxed);
                        Err(DlaError::WorkerLost {
                            reason: "worker dropped the reply channel".to_string(),
                        })
                    }
                }
            }
        }
    }

    /// Shut down and merge worker (and batcher) metrics.
    ///
    /// # Drain semantics
    ///
    /// Every request accepted by [`Self::submit`] is served before any
    /// thread is joined — nothing is dropped, in two stages:
    ///
    /// 1. **Channel drain.** Dropping the sender makes each worker's
    ///    `recv` yield every already-queued request before reporting
    ///    disconnect, so workers finish (or route into the batcher) all
    ///    of them and only then exit; joining here cannot strand queued
    ///    work.
    /// 2. **Admission-queue drain.** Only after every worker has exited
    ///    (i.e. no enqueuer remains) is the batch queue closed; `close`
    ///    makes the batcher flush every pending bucket immediately —
    ///    ignoring the coalescing wait — answer the replies, and exit.
    ///
    /// Shutdown is poison-tolerant: a worker that died to an unhandled
    /// panic is counted in `workers_lost` (the survivors' metrics still
    /// merge) instead of propagating the panic to the caller.
    ///
    /// The returned metrics merge every worker's counters plus the
    /// batcher's (batched GEMM latencies, [`super::metrics::BatchMetrics`],
    /// the latest shared-pool idle snapshot, and the submit-side fault
    /// counters).
    pub fn shutdown(mut self) -> Metrics {
        drop(self.tx.take());
        let mut all = Metrics::new();
        for h in self.handles.drain(..) {
            match h.join() {
                Ok(m) => all.merge(m),
                Err(_) => all.faults_mut().workers_lost += 1,
            }
        }
        if let Some(q) = self.batch_queue.take() {
            q.close();
        }
        if let Some(h) = self.batch_handle.take() {
            match h.join() {
                Ok(m) => all.merge(m),
                Err(_) => all.faults_mut().workers_lost += 1,
            }
        }
        let c = &self.counters;
        let f = all.faults_mut();
        f.invalid_inputs += c.invalid_inputs.load(Ordering::Relaxed);
        f.retries += c.retries.load(Ordering::Relaxed);
        f.queue_full_rejections += c.queue_full_rejections.load(Ordering::Relaxed);
        f.timeouts += c.timeouts.load(Ordering::Relaxed);
        f.workers_lost += c.workers_lost.load(Ordering::Relaxed);
        all
    }
}

impl Drop for CoordinatorServer {
    /// Dropping without [`Self::shutdown`] must not leak threads: close
    /// the channel and the admission queue so workers and the batcher
    /// unblock and exit (releasing their `Arc` on the shared pool, whose
    /// own `Drop` then retires the team). Metrics are lost and the
    /// threads are detached, not joined — call `shutdown` for the
    /// orderly two-stage drain. After `shutdown` every field is already
    /// `None` and this is a no-op.
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(q) = self.batch_queue.take() {
            q.close();
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::util::{MatrixF64, Pcg64};

    fn gemm_req(rng: &mut Pcg64, m: usize, n: usize, k: usize) -> DlaRequest {
        DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::random(m, k, rng),
            b: MatrixF64::random(k, n, rng),
            beta: 0.0,
            c: MatrixF64::zeros(m, n),
        }
    }

    #[test]
    fn server_round_trip() {
        let server =
            CoordinatorServer::start(ServerConfig::new(host_xeon(), ConfigMode::Refined)).unwrap();
        let mut rng = Pcg64::seed(9);
        let resp = server.call(gemm_req(&mut rng, 30, 20, 10)).unwrap();
        assert!(resp.seconds() >= 0.0);
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
        assert!(metrics.fault_stats().is_clean(), "healthy run must report no faults");
    }

    #[test]
    fn server_multiple_workers_process_all() {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined).with_workers(3),
        )
        .unwrap();
        let mut rng = Pcg64::seed(10);
        let mut pending = Vec::new();
        for i in 0..12 {
            let sz = 16 + (i % 4) * 8;
            pending.push(server.submit(gemm_req(&mut rng, sz, sz, 8)).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 12);
    }

    #[test]
    fn server_shares_one_gemm_pool_across_workers() {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3),
        )
        .unwrap();
        let mut rng = Pcg64::seed(11);
        let mut pending = Vec::new();
        for _ in 0..6 {
            pending.push(server.submit(gemm_req(&mut rng, 48, 40, 16)).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 6);
    }

    #[test]
    fn server_reports_pool_idle_stats_and_serves_lookahead_lu() {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_lookahead(Lookahead { depth: 1, panel_workers: 1 }),
        )
        .unwrap();
        let mut rng = Pcg64::seed(12);
        let a = MatrixF64::random_diag_dominant(64, &mut rng);
        let resp = server.call(DlaRequest::LuFactor { a: a.clone(), block: 16 }).unwrap();
        let DlaResponse::Lu { factors, .. } = resp else { panic!() };
        assert!(factors.reconstruction_error(&a) < 1e-10);
        let metrics = server.shutdown();
        let pool = metrics.pool_stats().expect("pooled server must surface pool stats");
        assert!(pool.jobs > 0, "LU trailing updates must have run pooled jobs: {pool:?}");
        assert!(metrics.summary().contains("gemm pool:"));
    }

    #[test]
    fn server_rejects_invalid_lookahead_up_front() {
        // The typed error must come back on the caller's thread from
        // start(), not surface inside detached workers.
        let err = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_lookahead(Lookahead { depth: 1, panel_workers: 3 }),
        )
        .err()
        .expect("invalid lookahead must fail start()");
        let DlaError::InvalidInput { reason } = err else {
            panic!("expected InvalidInput, got {err:?}")
        };
        assert!(reason.contains("lookahead"), "{reason}");
    }

    #[test]
    fn server_serves_both_dtypes_on_one_shared_pool() {
        use crate::util::MatrixF32;
        // One 3-thread pool; f64 GEMM + f32 GEMM + mixed-precision solve
        // all flow through it (the mixed solve factors in f32 on the
        // pooled pipeline and refines with f64 pooled GEMMs).
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3),
        )
        .unwrap();
        let mut rng = Pcg64::seed(31);
        let g64 = server.submit(gemm_req(&mut rng, 64, 48, 16)).unwrap();
        let a32 = MatrixF32::random(64, 24, &mut rng);
        let b32 = MatrixF32::random(24, 48, &mut rng);
        let g32 = server
            .submit(DlaRequest::GemmF32 {
                alpha: 1.0,
                a: a32.clone(),
                b: b32.clone(),
                beta: 0.0,
                c: MatrixF32::zeros(64, 48),
            })
            .unwrap();
        let a = crate::util::MatrixF64::random_diag_dominant(96, &mut rng);
        let x_true = crate::util::MatrixF64::random(96, 1, &mut rng);
        let mut rhs = crate::util::MatrixF64::zeros(96, 1);
        crate::gemm::gemm_reference(1.0, a.view(), x_true.view(), 0.0, &mut rhs.view_mut());
        let mx = server.submit(DlaRequest::MixedSolve { a, rhs, block: 24 }).unwrap();
        g64.recv().unwrap().unwrap();
        let DlaResponse::MatrixF32 { result, .. } = g32.recv().unwrap().unwrap() else {
            panic!()
        };
        let mut expect = MatrixF32::zeros(64, 48);
        crate::gemm::gemm_reference(1.0f32, a32.view(), b32.view(), 0.0f32, &mut expect.view_mut());
        assert!(result.max_abs_diff(&expect) < 1e-3);
        let DlaResponse::MixedSolve { x, fell_back, residual, .. } = mx.recv().unwrap().unwrap()
        else {
            panic!()
        };
        assert!(!fell_back);
        assert!(residual <= 1e-10, "{residual}");
        assert!(x.max_abs_diff(&x_true) < 1e-8);
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
        assert_eq!(metrics.count("gemm_f32"), 1);
        assert_eq!(metrics.count("mixed_lu"), 1);
        assert_eq!(metrics.refine_stats().solves, 1);
        let pool = metrics.pool_stats().expect("pooled server must surface pool stats");
        assert!(pool.jobs > 0, "both dtypes must have dispatched pooled jobs: {pool:?}");
        assert!(metrics.summary().contains("mixed precision:"));
    }

    #[test]
    fn server_propagates_errors() {
        let server =
            CoordinatorServer::start(ServerConfig::new(host_xeon(), ConfigMode::Refined)).unwrap();
        let resp = server.call(DlaRequest::LuFactor { a: MatrixF64::zeros(6, 6), block: 2 });
        assert_eq!(resp.err(), Some(DlaError::Singular { pivot: 0 }));
        server.shutdown();
    }

    #[test]
    fn submit_rejects_invalid_input_before_queueing() {
        let server =
            CoordinatorServer::start(ServerConfig::new(host_xeon(), ConfigMode::Refined)).unwrap();
        let mut a = MatrixF64::zeros(4, 4);
        a.set(1, 1, f64::NAN);
        let err = server
            .submit(DlaRequest::LuFactor { a, block: 2 })
            .expect_err("NaN operand must be rejected at admission");
        assert!(matches!(err, DlaError::InvalidInput { .. }), "{err:?}");
        assert!(!err.is_transient(), "invalid input is not retryable");
        let metrics = server.shutdown();
        assert_eq!(metrics.count("lu"), 0, "the request must never reach a worker");
        assert_eq!(metrics.fault_stats().invalid_inputs, 1);
    }

    #[test]
    fn deadline_expires_a_stalled_request() {
        // Worker stalls 300 ms on every dequeued request; the caller's
        // deadline is 40 ms. call() must give up at the deadline with a
        // typed Timeout, not block on the stalled worker.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_deadline(Duration::from_millis(40))
                .with_faults(FaultPlan::parse("stall:300").unwrap()),
        )
        .unwrap();
        let mut rng = Pcg64::seed(41);
        let t0 = Instant::now();
        let err = server.call(gemm_req(&mut rng, 16, 16, 8)).err().expect("must time out");
        assert!(matches!(err, DlaError::Timeout { .. }), "{err:?}");
        assert!(err.is_transient());
        assert!(t0.elapsed() < Duration::from_millis(250), "call must not wait out the stall");
        let metrics = server.shutdown();
        let f = metrics.fault_stats();
        // Caller-side timeout always fires; the worker may additionally
        // have dropped it as expired-in-queue after the stall.
        assert!(f.timeouts >= 1, "{f:?}");
    }

    #[test]
    fn forced_queue_full_retries_then_rejects() {
        // A burst longer than the retry budget: submit must retry with
        // backoff, then reject with QueueFull carrying the retry count.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_faults(FaultPlan::parse("queuefull:100").unwrap()),
        )
        .unwrap();
        let mut rng = Pcg64::seed(42);
        let err = server.submit(gemm_req(&mut rng, 16, 16, 8)).expect_err("must reject");
        assert_eq!(err, DlaError::QueueFull { retries: MAX_ADMISSION_ATTEMPTS });
        assert!(err.is_transient());
        // A burst shorter than the budget is absorbed by the retries.
        let short = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_faults(FaultPlan::parse("queuefull:3").unwrap()),
        )
        .unwrap();
        let rx = short.submit(gemm_req(&mut rng, 16, 16, 8)).expect("retries must absorb burst");
        rx.recv().unwrap().unwrap();
        let metrics = short.shutdown();
        let f = metrics.fault_stats();
        assert_eq!(f.retries, 3, "{f:?}");
        assert_eq!(f.queue_full_rejections, 0, "{f:?}");
        server.shutdown();
    }

    #[test]
    fn batching_server_coalesces_small_gemms() {
        // A long wait + a small full-trigger: the only way requests get
        // served promptly is the full-bucket dispatch, so coalescing is
        // deterministic (the remainder flushes at shutdown).
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3)
                .with_batching(
                    BatchPolicy::default().with_max_batch(4).with_wait_us(5_000_000).admit_all(),
                ),
        )
        .unwrap();
        let mut rng = Pcg64::seed(21);
        let mut pending = Vec::new();
        for _ in 0..8 {
            pending.push(server.submit(gemm_req(&mut rng, 24, 24, 12)).unwrap());
        }
        // Shutdown drains everything (including a not-yet-full remainder
        // bucket), so the replies are all available afterwards.
        let metrics = server.shutdown();
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(metrics.count("gemm"), 8);
        let b = metrics.batch_stats();
        assert_eq!(b.total_requests(), 8, "every small gemm goes through the batcher: {b:?}");
        assert!(b.batches >= 1, "the full trigger must have fired: {b:?}");
        // The first full-bucket dispatch alone coalesces max_batch
        // requests.
        assert!(b.coalesced_requests >= 4, "{b:?}");
        assert_eq!(b.queue_wait_ns.count, 8);
        assert!(metrics.summary().contains("batching:"));
    }

    #[test]
    fn batch_queue_bounds_pending_entries() {
        // The admission queue must preserve the server's backpressure: at
        // the bound, try_enqueue hands the entry back (the worker serves
        // it solo); draining a bucket frees capacity.
        let q = BatchQueue::new(BatchPolicy::default().with_max_batch(2), 2);
        let dims = GemmDims::new(8, 8, 8);
        let entry = || PendingGemm {
            req: DlaRequest::Gemm {
                alpha: 1.0,
                a: MatrixF64::zeros(8, 8),
                b: MatrixF64::zeros(8, 8),
                beta: 0.0,
                c: MatrixF64::zeros(8, 8),
            },
            reply: mpsc::channel().0,
            enqueued: Instant::now(),
            deadline: None,
        };
        assert!(q.try_enqueue(dims, entry()).is_ok());
        assert!(q.try_enqueue(dims, entry()).is_ok());
        assert!(q.try_enqueue(dims, entry()).is_err(), "bound must reject the third entry");
        // The full bucket is dispatchable; draining frees capacity.
        let batch = q.next_batch().expect("full bucket ready");
        assert_eq!(batch.len(), 2);
        assert!(q.try_enqueue(dims, entry()).is_ok());
    }

    #[test]
    fn tight_deadlines_bypass_the_batcher() {
        // An hour-long coalescing window with a 100 ms deadline: a
        // batched request would park past its deadline, so the
        // fits_deadline gate must route it to the solo path, where it
        // is served promptly.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_deadline(Duration::from_millis(30_000))
                .with_batching(BatchPolicy::default().with_wait_us(3_600_000_000).admit_all()),
        )
        .unwrap();
        let mut rng = Pcg64::seed(43);
        server.call(gemm_req(&mut rng, 24, 24, 12)).unwrap();
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
        assert_eq!(
            metrics.batch_stats().total_requests(),
            0,
            "deadline-tight gemm must not park in the batcher"
        );
    }

    #[test]
    fn pinned_disabled_batching_beats_env() {
        // BatchPolicy::disabled() must force the solo path even when the
        // CI matrix exports DLA_BATCH=1.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_batching(BatchPolicy::disabled()),
        )
        .unwrap();
        let mut rng = Pcg64::seed(22);
        server.call(gemm_req(&mut rng, 24, 24, 12)).unwrap();
        let metrics = server.shutdown();
        assert_eq!(metrics.count("gemm"), 1);
        assert_eq!(metrics.batch_stats().total_requests(), 0);
    }

    #[test]
    fn factorizations_bypass_the_batcher() {
        // With an hour-long coalescing window, a batched request would
        // visibly hang — factorizations must come back via the solo path
        // immediately, composing with lookahead on the shared pool.
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_gemm_threads(3)
                .with_batching(BatchPolicy::default().with_wait_us(3_600_000_000).admit_all()),
        )
        .unwrap();
        let mut rng = Pcg64::seed(23);
        let a = MatrixF64::random_diag_dominant(48, &mut rng);
        let resp = server.call(DlaRequest::LuFactor { a: a.clone(), block: 16 }).unwrap();
        let DlaResponse::Lu { factors, .. } = resp else { panic!() };
        assert!(factors.reconstruction_error(&a) < 1e-10);
        let metrics = server.shutdown();
        assert_eq!(metrics.count("lu"), 1);
        assert_eq!(metrics.batch_stats().total_requests(), 0, "LU must not touch the batcher");
    }
}
