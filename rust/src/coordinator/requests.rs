//! Request/response types of the coordinator.

use crate::lapack::LuFactors;
use crate::util::MatrixF64;

/// A DLA service request.
pub enum DlaRequest {
    /// `C = alpha * A * B + beta * C`.
    Gemm { alpha: f64, a: MatrixF64, b: MatrixF64, beta: f64, c: MatrixF64 },
    /// Blocked LU with partial pivoting.
    LuFactor { a: MatrixF64, block: usize },
    /// Blocked lower Cholesky (SPD input).
    Cholesky { a: MatrixF64, block: usize },
}

impl DlaRequest {
    /// Kind label for metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            DlaRequest::Gemm { .. } => "gemm",
            DlaRequest::LuFactor { .. } => "lu",
            DlaRequest::Cholesky { .. } => "cholesky",
        }
    }

    /// Nominal flop count (for throughput accounting).
    pub fn flops(&self) -> f64 {
        match self {
            DlaRequest::Gemm { a, b, .. } => 2.0 * a.rows() as f64 * b.cols() as f64 * a.cols() as f64,
            DlaRequest::LuFactor { a, .. } => crate::lapack::lu::lu_flops(a.rows()),
            DlaRequest::Cholesky { a, .. } => (a.rows() as f64).powi(3) / 3.0,
        }
    }
}

/// A DLA service response.
pub enum DlaResponse {
    /// Result matrix (GEMM / Cholesky), optionally with the configuration
    /// string the co-design selector chose.
    Matrix { result: MatrixF64, config: Option<String>, seconds: f64 },
    /// LU factors.
    Lu { factors: LuFactors, seconds: f64 },
}

impl DlaResponse {
    pub fn seconds(&self) -> f64 {
        match self {
            DlaResponse::Matrix { seconds, .. } | DlaResponse::Lu { seconds, .. } => *seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_and_flops() {
        let req = DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::zeros(10, 20),
            b: MatrixF64::zeros(20, 30),
            beta: 0.0,
            c: MatrixF64::zeros(10, 30),
        };
        assert_eq!(req.kind(), "gemm");
        assert_eq!(req.flops(), 2.0 * 10.0 * 30.0 * 20.0);
        let lu = DlaRequest::LuFactor { a: MatrixF64::zeros(30, 30), block: 8 };
        assert_eq!(lu.kind(), "lu");
        assert!(lu.flops() > 0.0);
    }
}
