//! Request/response types of the coordinator.

// The serving path must stay panic-free: every unwrap/expect below is
// either allow-listed with a justification or lives in test code.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use crate::lapack::LuFactors;
use crate::model::GemmDims;
use crate::util::{DlaError, DType, MatrixF32, MatrixF64};

/// A DLA service request.
///
/// A request carries *what* to compute; *how urgently* rides the submit
/// API instead (`CoordinatorServer::submit_at` /
/// `submit_async_at` take a [`Priority`] tier) so existing construction
/// sites — and serialized request shapes — stay unchanged.
///
/// [`Priority`]: crate::coordinator::qos::Priority
pub enum DlaRequest {
    /// `C = alpha * A * B + beta * C` (FP64).
    Gemm { alpha: f64, a: MatrixF64, b: MatrixF64, beta: f64, c: MatrixF64 },
    /// `C = alpha * A * B + beta * C` in f32: same pooled drivers, the
    /// model's f32-width (larger) cache configs and double-lane kernels.
    GemmF32 { alpha: f32, a: MatrixF32, b: MatrixF32, beta: f32, c: MatrixF32 },
    /// Blocked LU with partial pivoting.
    LuFactor { a: MatrixF64, block: usize },
    /// Mixed-precision solve of `A x = rhs`: factor in f32 on the pooled
    /// lookahead pipeline, iteratively refine the solution to f64
    /// residual accuracy (with a clean f64 fallback) — see
    /// [`crate::lapack::refine`].
    MixedSolve { a: MatrixF64, rhs: MatrixF64, block: usize },
    /// Blocked lower Cholesky (SPD input).
    Cholesky { a: MatrixF64, block: usize },
}

impl DlaRequest {
    /// Kind label for metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            DlaRequest::Gemm { .. } => "gemm",
            DlaRequest::GemmF32 { .. } => "gemm_f32",
            DlaRequest::LuFactor { .. } => "lu",
            DlaRequest::MixedSolve { .. } => "mixed_lu",
            DlaRequest::Cholesky { .. } => "cholesky",
        }
    }

    /// The GEMM problem shape, for requests that are GEMMs of either
    /// precision — half of the batch scheduler's bucketing/admission key
    /// (the other half is [`Self::gemm_dtype`], so precisions never
    /// share a bucket). `None` for factorizations, which always keep
    /// the solo path.
    pub fn gemm_dims(&self) -> Option<GemmDims> {
        match self {
            DlaRequest::Gemm { a, b, .. } => Some(GemmDims::new(a.rows(), b.cols(), a.cols())),
            DlaRequest::GemmF32 { a, b, .. } => Some(GemmDims::new(a.rows(), b.cols(), a.cols())),
            _ => None,
        }
    }

    /// The element type of a GEMM request — the dtype half of the batch
    /// scheduler's bucket key. `None` for non-GEMM kinds.
    pub fn gemm_dtype(&self) -> Option<DType> {
        match self {
            DlaRequest::Gemm { .. } => Some(DType::F64),
            DlaRequest::GemmF32 { .. } => Some(DType::F32),
            _ => None,
        }
    }

    /// Are the operand shapes of a GEMM request (either precision)
    /// mutually consistent? `false` for non-GEMM kinds. (Inconsistent
    /// requests are never admitted to the batcher; the solo path
    /// surfaces the mismatch exactly as before.)
    pub fn gemm_shape_consistent(&self) -> bool {
        match self {
            DlaRequest::Gemm { a, b, c, .. } => {
                a.cols() == b.rows() && c.rows() == a.rows() && c.cols() == b.cols()
            }
            DlaRequest::GemmF32 { a, b, c, .. } => {
                a.cols() == b.rows() && c.rows() == a.rows() && c.cols() == b.cols()
            }
            _ => false,
        }
    }

    /// Admission validation: reject malformed operands with a typed
    /// [`DlaError::InvalidInput`] *before* any pool work is enqueued —
    /// mismatched dimensions, degenerate blocking, and non-finite
    /// entries (NaN/Inf) that would otherwise propagate garbage or blow
    /// up deep inside a kernel. The finite scan is O(elements), noise
    /// next to the O(n³) work a request buys.
    pub fn validate(&self) -> Result<(), DlaError> {
        let invalid = |reason: String| Err(DlaError::InvalidInput { reason });
        match self {
            DlaRequest::Gemm { alpha, a, b, beta, c } => {
                if !self.gemm_shape_consistent() {
                    return invalid(format!(
                        "gemm shape mismatch: a {}x{}, b {}x{}, c {}x{}",
                        a.rows(), a.cols(), b.rows(), b.cols(), c.rows(), c.cols()
                    ));
                }
                if !alpha.is_finite() || !beta.is_finite() {
                    return invalid("non-finite gemm scalar (alpha/beta)".to_string());
                }
                for (name, m) in [("a", a), ("b", b), ("c", c)] {
                    if !m.all_finite() {
                        return invalid(format!("non-finite entries in gemm operand {name}"));
                    }
                }
            }
            DlaRequest::GemmF32 { alpha, a, b, beta, c } => {
                if !self.gemm_shape_consistent() {
                    return invalid(format!(
                        "gemm_f32 shape mismatch: a {}x{}, b {}x{}, c {}x{}",
                        a.rows(), a.cols(), b.rows(), b.cols(), c.rows(), c.cols()
                    ));
                }
                if !alpha.is_finite() || !beta.is_finite() {
                    return invalid("non-finite gemm_f32 scalar (alpha/beta)".to_string());
                }
                for (name, m) in [("a", a), ("b", b), ("c", c)] {
                    if !m.all_finite() {
                        return invalid(format!("non-finite entries in gemm_f32 operand {name}"));
                    }
                }
            }
            DlaRequest::LuFactor { a, block } => {
                validate_factor("lu", a, *block)?;
            }
            DlaRequest::MixedSolve { a, rhs, block } => {
                validate_factor("mixed_lu", a, *block)?;
                if rhs.rows() != a.rows() {
                    return invalid(format!(
                        "mixed_lu rhs has {} rows but the matrix is {}x{}",
                        rhs.rows(), a.rows(), a.cols()
                    ));
                }
                if !rhs.all_finite() {
                    return invalid("non-finite entries in mixed_lu rhs".to_string());
                }
            }
            DlaRequest::Cholesky { a, block } => {
                validate_factor("cholesky", a, *block)?;
            }
        }
        Ok(())
    }

    /// The synthetic request the `flood:N` fault injects at admission: a
    /// small, finite, well-formed f64 GEMM, cheap enough that N of them
    /// stress the queue rather than the pool. Injected at `Background`
    /// tier with no reply consumer, so the overload drill exercises the
    /// tier queues and the shedding policy end to end without a load
    /// generator.
    pub fn flood_probe() -> DlaRequest {
        DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::zeros(16, 8),
            b: MatrixF64::zeros(8, 16),
            beta: 0.0,
            c: MatrixF64::zeros(16, 16),
        }
    }

    /// Nominal flop count (for throughput accounting).
    pub fn flops(&self) -> f64 {
        match self {
            DlaRequest::Gemm { a, b, .. } => 2.0 * a.rows() as f64 * b.cols() as f64 * a.cols() as f64,
            DlaRequest::GemmF32 { a, b, .. } => {
                2.0 * a.rows() as f64 * b.cols() as f64 * a.cols() as f64
            }
            DlaRequest::LuFactor { a, .. } => crate::lapack::lu::lu_flops(a.rows()),
            // The O(n³) factorization dominates; refinement is O(n²) per
            // iteration.
            DlaRequest::MixedSolve { a, .. } => crate::lapack::lu::lu_flops(a.rows()),
            DlaRequest::Cholesky { a, .. } => (a.rows() as f64).powi(3) / 3.0,
        }
    }
}

/// Shared validation of the square-factorization request kinds.
fn validate_factor(kind: &str, a: &MatrixF64, block: usize) -> Result<(), DlaError> {
    let invalid = |reason: String| Err(DlaError::InvalidInput { reason });
    if a.rows() != a.cols() {
        return invalid(format!("{kind} needs a square matrix, got {}x{}", a.rows(), a.cols()));
    }
    if a.rows() == 0 {
        return invalid(format!("{kind} on an empty matrix"));
    }
    if block == 0 {
        return invalid(format!("{kind} block size must be >= 1"));
    }
    if !a.all_finite() {
        return invalid(format!("non-finite entries in {kind} matrix"));
    }
    Ok(())
}

/// A DLA service response.
pub enum DlaResponse {
    /// Result matrix (GEMM / Cholesky), optionally with the configuration
    /// string the co-design selector chose.
    Matrix { result: MatrixF64, config: Option<String>, seconds: f64 },
    /// f32 result matrix (the `GemmF32` request kind).
    MatrixF32 { result: MatrixF32, config: Option<String>, seconds: f64 },
    /// LU factors.
    Lu { factors: LuFactors, seconds: f64 },
    /// Mixed-precision solve: the f64 solution plus the refinement
    /// telemetry (iterations, fallback, final scaled residual).
    MixedSolve {
        x: MatrixF64,
        iterations: usize,
        fell_back: bool,
        residual: f64,
        seconds: f64,
    },
}

impl DlaResponse {
    pub fn seconds(&self) -> f64 {
        match self {
            DlaResponse::Matrix { seconds, .. }
            | DlaResponse::MatrixF32 { seconds, .. }
            | DlaResponse::Lu { seconds, .. }
            | DlaResponse::MixedSolve { seconds, .. } => *seconds,
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn reason(req: &DlaRequest) -> String {
        match req.validate() {
            Err(DlaError::InvalidInput { reason }) => reason,
            other => panic!("expected InvalidInput, got {other:?}"),
        }
    }

    #[test]
    fn validation_accepts_well_formed_requests() {
        let ok = DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::zeros(10, 20),
            b: MatrixF64::zeros(20, 30),
            beta: 0.5,
            c: MatrixF64::zeros(10, 30),
        };
        assert!(ok.validate().is_ok());
        assert!(DlaRequest::LuFactor { a: MatrixF64::identity(8), block: 4 }.validate().is_ok());
        assert!(DlaRequest::MixedSolve {
            a: MatrixF64::identity(8),
            rhs: MatrixF64::zeros(8, 2),
            block: 4,
        }
        .validate()
        .is_ok());
    }

    #[test]
    fn validation_rejects_shape_mismatch_and_nan() {
        let bad_shape = DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::zeros(10, 21),
            b: MatrixF64::zeros(20, 30),
            beta: 0.0,
            c: MatrixF64::zeros(10, 30),
        };
        assert!(reason(&bad_shape).contains("shape mismatch"));
        let mut a = MatrixF64::identity(6);
        a[(2, 3)] = f64::NAN;
        let nan_lu = DlaRequest::LuFactor { a, block: 2 };
        assert!(reason(&nan_lu).contains("non-finite"));
        let mut b = MatrixF64::zeros(4, 4);
        b[(0, 0)] = f64::INFINITY;
        let inf_gemm = DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::zeros(4, 4),
            b,
            beta: 0.0,
            c: MatrixF64::zeros(4, 4),
        };
        assert!(reason(&inf_gemm).contains("non-finite"));
        let bad_scalar = DlaRequest::Gemm {
            alpha: f64::NAN,
            a: MatrixF64::zeros(4, 4),
            b: MatrixF64::zeros(4, 4),
            beta: 0.0,
            c: MatrixF64::zeros(4, 4),
        };
        assert!(reason(&bad_scalar).contains("scalar"));
    }

    #[test]
    fn validation_rejects_degenerate_factorizations() {
        let rect = DlaRequest::LuFactor { a: MatrixF64::zeros(8, 6), block: 2 };
        assert!(reason(&rect).contains("square"));
        let no_block = DlaRequest::Cholesky { a: MatrixF64::identity(8), block: 0 };
        assert!(reason(&no_block).contains("block"));
        let short_rhs = DlaRequest::MixedSolve {
            a: MatrixF64::identity(8),
            rhs: MatrixF64::zeros(6, 1),
            block: 4,
        };
        assert!(reason(&short_rhs).contains("rhs"));
        let mut f32_c = MatrixF32::zeros(4, 4);
        f32_c[(1, 1)] = f32::NAN;
        let nan_f32 = DlaRequest::GemmF32 {
            alpha: 1.0,
            a: MatrixF32::zeros(4, 4),
            b: MatrixF32::zeros(4, 4),
            beta: 0.0,
            c: f32_c,
        };
        assert!(reason(&nan_f32).contains("non-finite"));
    }

    #[test]
    fn kinds_and_flops() {
        let req = DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::zeros(10, 20),
            b: MatrixF64::zeros(20, 30),
            beta: 0.0,
            c: MatrixF64::zeros(10, 30),
        };
        assert_eq!(req.kind(), "gemm");
        assert_eq!(req.flops(), 2.0 * 10.0 * 30.0 * 20.0);
        assert_eq!(req.gemm_dims(), Some(GemmDims::new(10, 30, 20)));
        assert!(req.gemm_shape_consistent());
        let lu = DlaRequest::LuFactor { a: MatrixF64::zeros(30, 30), block: 8 };
        assert_eq!(lu.kind(), "lu");
        assert!(lu.flops() > 0.0);
        assert_eq!(lu.gemm_dims(), None);
        assert!(!lu.gemm_shape_consistent());
        let bad = DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::zeros(10, 21),
            b: MatrixF64::zeros(20, 30),
            beta: 0.0,
            c: MatrixF64::zeros(10, 30),
        };
        assert!(!bad.gemm_shape_consistent());
    }

    #[test]
    fn flood_probe_is_a_valid_batchable_gemm() {
        let p = DlaRequest::flood_probe();
        assert!(p.validate().is_ok(), "the drill must never count as an invalid input");
        assert_eq!(p.kind(), "gemm");
        assert_eq!(p.gemm_dims(), Some(GemmDims::new(16, 16, 8)));
        assert!(p.gemm_shape_consistent());
    }

    #[test]
    fn f32_gemms_bucket_by_dtype_and_mixed_kinds_bypass_the_batcher() {
        let g32 = DlaRequest::GemmF32 {
            alpha: 1.0,
            a: MatrixF32::zeros(10, 20),
            b: MatrixF32::zeros(20, 30),
            beta: 0.0,
            c: MatrixF32::zeros(10, 30),
        };
        assert_eq!(g32.kind(), "gemm_f32");
        assert_eq!(g32.flops(), 2.0 * 10.0 * 30.0 * 20.0);
        assert_eq!(
            g32.gemm_dims(),
            Some(GemmDims::new(10, 30, 20)),
            "f32 GEMMs are batchable; dtype keeps them in their own buckets"
        );
        assert_eq!(g32.gemm_dtype(), Some(DType::F32));
        assert!(g32.gemm_shape_consistent(), "well-formed f32 shapes are consistent");
        let bad32 = DlaRequest::GemmF32 {
            alpha: 1.0,
            a: MatrixF32::zeros(10, 21),
            b: MatrixF32::zeros(20, 30),
            beta: 0.0,
            c: MatrixF32::zeros(10, 30),
        };
        assert!(!bad32.gemm_shape_consistent());
        let mx = DlaRequest::MixedSolve {
            a: MatrixF64::zeros(30, 30),
            rhs: MatrixF64::zeros(30, 2),
            block: 8,
        };
        assert_eq!(mx.kind(), "mixed_lu");
        assert_eq!(mx.gemm_dims(), None, "factorization-class: bypasses the batcher");
        assert_eq!(mx.gemm_dtype(), None);
        assert!(!mx.gemm_shape_consistent());
        assert!(mx.flops() > 0.0);
    }
}
