//! Request/response types of the coordinator.

use crate::lapack::LuFactors;
use crate::model::GemmDims;
use crate::util::{MatrixF32, MatrixF64};

/// A DLA service request.
pub enum DlaRequest {
    /// `C = alpha * A * B + beta * C` (FP64).
    Gemm { alpha: f64, a: MatrixF64, b: MatrixF64, beta: f64, c: MatrixF64 },
    /// `C = alpha * A * B + beta * C` in f32: same pooled drivers, the
    /// model's f32-width (larger) cache configs and double-lane kernels.
    GemmF32 { alpha: f32, a: MatrixF32, b: MatrixF32, beta: f32, c: MatrixF32 },
    /// Blocked LU with partial pivoting.
    LuFactor { a: MatrixF64, block: usize },
    /// Mixed-precision solve of `A x = rhs`: factor in f32 on the pooled
    /// lookahead pipeline, iteratively refine the solution to f64
    /// residual accuracy (with a clean f64 fallback) — see
    /// [`crate::lapack::refine`].
    MixedSolve { a: MatrixF64, rhs: MatrixF64, block: usize },
    /// Blocked lower Cholesky (SPD input).
    Cholesky { a: MatrixF64, block: usize },
}

impl DlaRequest {
    /// Kind label for metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            DlaRequest::Gemm { .. } => "gemm",
            DlaRequest::GemmF32 { .. } => "gemm_f32",
            DlaRequest::LuFactor { .. } => "lu",
            DlaRequest::MixedSolve { .. } => "mixed_lu",
            DlaRequest::Cholesky { .. } => "cholesky",
        }
    }

    /// The GEMM problem shape, for requests that are **f64** GEMMs — the
    /// batch scheduler's bucketing/admission key. `None` for
    /// factorizations and for f32 GEMMs (the admission queue buckets one
    /// dtype; f32 requests keep the solo path on the shared pool —
    /// dtype-aware buckets are a ROADMAP follow-on).
    pub fn gemm_dims(&self) -> Option<GemmDims> {
        match self {
            DlaRequest::Gemm { a, b, .. } => Some(GemmDims::new(a.rows(), b.cols(), a.cols())),
            _ => None,
        }
    }

    /// Are the operand shapes of a GEMM request (either precision)
    /// mutually consistent? `false` for non-GEMM kinds. (Inconsistent
    /// requests are never admitted to the batcher; the solo path
    /// surfaces the mismatch exactly as before.)
    pub fn gemm_shape_consistent(&self) -> bool {
        match self {
            DlaRequest::Gemm { a, b, c, .. } => {
                a.cols() == b.rows() && c.rows() == a.rows() && c.cols() == b.cols()
            }
            DlaRequest::GemmF32 { a, b, c, .. } => {
                a.cols() == b.rows() && c.rows() == a.rows() && c.cols() == b.cols()
            }
            _ => false,
        }
    }

    /// Nominal flop count (for throughput accounting).
    pub fn flops(&self) -> f64 {
        match self {
            DlaRequest::Gemm { a, b, .. } => 2.0 * a.rows() as f64 * b.cols() as f64 * a.cols() as f64,
            DlaRequest::GemmF32 { a, b, .. } => {
                2.0 * a.rows() as f64 * b.cols() as f64 * a.cols() as f64
            }
            DlaRequest::LuFactor { a, .. } => crate::lapack::lu::lu_flops(a.rows()),
            // The O(n³) factorization dominates; refinement is O(n²) per
            // iteration.
            DlaRequest::MixedSolve { a, .. } => crate::lapack::lu::lu_flops(a.rows()),
            DlaRequest::Cholesky { a, .. } => (a.rows() as f64).powi(3) / 3.0,
        }
    }
}

/// A DLA service response.
pub enum DlaResponse {
    /// Result matrix (GEMM / Cholesky), optionally with the configuration
    /// string the co-design selector chose.
    Matrix { result: MatrixF64, config: Option<String>, seconds: f64 },
    /// f32 result matrix (the `GemmF32` request kind).
    MatrixF32 { result: MatrixF32, config: Option<String>, seconds: f64 },
    /// LU factors.
    Lu { factors: LuFactors, seconds: f64 },
    /// Mixed-precision solve: the f64 solution plus the refinement
    /// telemetry (iterations, fallback, final scaled residual).
    MixedSolve {
        x: MatrixF64,
        iterations: usize,
        fell_back: bool,
        residual: f64,
        seconds: f64,
    },
}

impl DlaResponse {
    pub fn seconds(&self) -> f64 {
        match self {
            DlaResponse::Matrix { seconds, .. }
            | DlaResponse::MatrixF32 { seconds, .. }
            | DlaResponse::Lu { seconds, .. }
            | DlaResponse::MixedSolve { seconds, .. } => *seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_and_flops() {
        let req = DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::zeros(10, 20),
            b: MatrixF64::zeros(20, 30),
            beta: 0.0,
            c: MatrixF64::zeros(10, 30),
        };
        assert_eq!(req.kind(), "gemm");
        assert_eq!(req.flops(), 2.0 * 10.0 * 30.0 * 20.0);
        assert_eq!(req.gemm_dims(), Some(GemmDims::new(10, 30, 20)));
        assert!(req.gemm_shape_consistent());
        let lu = DlaRequest::LuFactor { a: MatrixF64::zeros(30, 30), block: 8 };
        assert_eq!(lu.kind(), "lu");
        assert!(lu.flops() > 0.0);
        assert_eq!(lu.gemm_dims(), None);
        assert!(!lu.gemm_shape_consistent());
        let bad = DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::zeros(10, 21),
            b: MatrixF64::zeros(20, 30),
            beta: 0.0,
            c: MatrixF64::zeros(10, 30),
        };
        assert!(!bad.gemm_shape_consistent());
    }

    #[test]
    fn f32_and_mixed_kinds_bypass_the_batcher() {
        let g32 = DlaRequest::GemmF32 {
            alpha: 1.0,
            a: MatrixF32::zeros(10, 20),
            b: MatrixF32::zeros(20, 30),
            beta: 0.0,
            c: MatrixF32::zeros(10, 30),
        };
        assert_eq!(g32.kind(), "gemm_f32");
        assert_eq!(g32.flops(), 2.0 * 10.0 * 30.0 * 20.0);
        assert_eq!(g32.gemm_dims(), None, "f32 GEMMs keep the solo path");
        assert!(g32.gemm_shape_consistent(), "well-formed f32 shapes are consistent");
        let bad32 = DlaRequest::GemmF32 {
            alpha: 1.0,
            a: MatrixF32::zeros(10, 21),
            b: MatrixF32::zeros(20, 30),
            beta: 0.0,
            c: MatrixF32::zeros(10, 30),
        };
        assert!(!bad32.gemm_shape_consistent());
        let mx = DlaRequest::MixedSolve {
            a: MatrixF64::zeros(30, 30),
            rhs: MatrixF64::zeros(30, 2),
            block: 8,
        };
        assert_eq!(mx.kind(), "mixed_lu");
        assert_eq!(mx.gemm_dims(), None, "factorization-class: bypasses the batcher");
        assert!(!mx.gemm_shape_consistent());
        assert!(mx.flops() > 0.0);
    }
}
