//! Request/response types of the coordinator.

use crate::lapack::LuFactors;
use crate::model::GemmDims;
use crate::util::MatrixF64;

/// A DLA service request.
pub enum DlaRequest {
    /// `C = alpha * A * B + beta * C`.
    Gemm { alpha: f64, a: MatrixF64, b: MatrixF64, beta: f64, c: MatrixF64 },
    /// Blocked LU with partial pivoting.
    LuFactor { a: MatrixF64, block: usize },
    /// Blocked lower Cholesky (SPD input).
    Cholesky { a: MatrixF64, block: usize },
}

impl DlaRequest {
    /// Kind label for metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            DlaRequest::Gemm { .. } => "gemm",
            DlaRequest::LuFactor { .. } => "lu",
            DlaRequest::Cholesky { .. } => "cholesky",
        }
    }

    /// The GEMM problem shape, for requests that are GEMMs — the batch
    /// scheduler's bucketing/admission key. `None` for factorizations
    /// (they bypass the batcher and keep the lookahead path).
    pub fn gemm_dims(&self) -> Option<GemmDims> {
        match self {
            DlaRequest::Gemm { a, b, .. } => Some(GemmDims::new(a.rows(), b.cols(), a.cols())),
            _ => None,
        }
    }

    /// Are the operand shapes of a GEMM request mutually consistent?
    /// (Inconsistent requests are never admitted to the batcher; the
    /// solo path surfaces the mismatch exactly as before.)
    pub fn gemm_shape_consistent(&self) -> bool {
        match self {
            DlaRequest::Gemm { a, b, c, .. } => {
                a.cols() == b.rows() && c.rows() == a.rows() && c.cols() == b.cols()
            }
            _ => false,
        }
    }

    /// Nominal flop count (for throughput accounting).
    pub fn flops(&self) -> f64 {
        match self {
            DlaRequest::Gemm { a, b, .. } => 2.0 * a.rows() as f64 * b.cols() as f64 * a.cols() as f64,
            DlaRequest::LuFactor { a, .. } => crate::lapack::lu::lu_flops(a.rows()),
            DlaRequest::Cholesky { a, .. } => (a.rows() as f64).powi(3) / 3.0,
        }
    }
}

/// A DLA service response.
pub enum DlaResponse {
    /// Result matrix (GEMM / Cholesky), optionally with the configuration
    /// string the co-design selector chose.
    Matrix { result: MatrixF64, config: Option<String>, seconds: f64 },
    /// LU factors.
    Lu { factors: LuFactors, seconds: f64 },
}

impl DlaResponse {
    pub fn seconds(&self) -> f64 {
        match self {
            DlaResponse::Matrix { seconds, .. } | DlaResponse::Lu { seconds, .. } => *seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_and_flops() {
        let req = DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::zeros(10, 20),
            b: MatrixF64::zeros(20, 30),
            beta: 0.0,
            c: MatrixF64::zeros(10, 30),
        };
        assert_eq!(req.kind(), "gemm");
        assert_eq!(req.flops(), 2.0 * 10.0 * 30.0 * 20.0);
        assert_eq!(req.gemm_dims(), Some(GemmDims::new(10, 30, 20)));
        assert!(req.gemm_shape_consistent());
        let lu = DlaRequest::LuFactor { a: MatrixF64::zeros(30, 30), block: 8 };
        assert_eq!(lu.kind(), "lu");
        assert!(lu.flops() > 0.0);
        assert_eq!(lu.gemm_dims(), None);
        assert!(!lu.gemm_shape_consistent());
        let bad = DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::zeros(10, 21),
            b: MatrixF64::zeros(20, 30),
            beta: 0.0,
            c: MatrixF64::zeros(10, 30),
        };
        assert!(!bad.gemm_shape_consistent());
    }
}
