//! PJRT-backed blocked LU: the Rust coordinator drives the loop F1 of
//! paper Figure 2, executing one compiled `lu_step` artifact per
//! iteration — the end-to-end three-layer path (Rust -> XLA -> Pallas
//! GEMM) with Python nowhere at runtime.

use anyhow::{bail, Context, Result};

use crate::runtime::convert::{
    literal_to_bool, literal_to_matrix, literal_to_vec_i64, matrix_to_literal, scalar_i64,
    vec_to_literal_i64,
};
use crate::runtime::{execute_tupled, Registry};
use crate::util::{MatrixF64, Stopwatch};

/// Result of an artifact-driven LU run.
pub struct LuArtifactResult {
    /// Factored matrix (L strict lower + U upper).
    pub lu: MatrixF64,
    /// Global pivot rows (LAPACK convention).
    pub pivots: Vec<usize>,
    /// Seconds per step (the latency series the e2e example reports).
    pub step_seconds: Vec<f64>,
    /// Total wall time.
    pub total_seconds: f64,
}

impl LuArtifactResult {
    pub fn gflops(&self) -> f64 {
        crate::lapack::lu::lu_flops(self.lu.rows()) / self.total_seconds / 1e9
    }
}

/// Run the blocked LU through the `lu_step_s{s}_b{b}` artifact.
pub fn lu_via_artifacts(registry: &Registry, a0: &MatrixF64, block: usize) -> Result<LuArtifactResult> {
    let s = a0.rows();
    if a0.cols() != s {
        bail!("LU requires a square matrix");
    }
    let art = registry
        .find_lu_step(s, block)
        .with_context(|| format!("no lu_step artifact for s={s} b={block} (see aot.py)"))?;
    let total = Stopwatch::start();
    let mut a_lit = matrix_to_literal(a0)?;
    let mut piv_lit = vec_to_literal_i64(&(0..s as i64).collect::<Vec<_>>());
    let mut step_seconds = Vec::new();
    let mut k = 0usize;
    while k < s {
        let sw = Stopwatch::start();
        let outs = execute_tupled(&art.exe, &[a_lit, piv_lit, scalar_i64(k as i64)])
            .with_context(|| format!("lu_step at k={k}"))?;
        if outs.len() != 3 {
            bail!("lu_step returned {} outputs, expected 3", outs.len());
        }
        let mut it = outs.into_iter();
        a_lit = it.next().unwrap();
        piv_lit = it.next().unwrap();
        let ok = literal_to_bool(&it.next().unwrap())?;
        if !ok {
            bail!("singular pivot in panel starting at column {k}");
        }
        step_seconds.push(sw.elapsed_secs());
        k += block;
    }
    let lu = literal_to_matrix(&a_lit)?;
    let pivots: Vec<usize> = literal_to_vec_i64(&piv_lit)?.into_iter().map(|v| v as usize).collect();
    Ok(LuArtifactResult { lu, pivots, step_seconds, total_seconds: total.elapsed_secs() })
}

/// Run the single-artifact whole factorization (`lu_full`), for
/// comparison with the step-driven path.
pub fn lu_full_via_artifact(registry: &Registry, a0: &MatrixF64, block: usize) -> Result<LuArtifactResult> {
    let s = a0.rows();
    let art = registry
        .find_lu_full(s, block)
        .with_context(|| format!("no lu_full artifact for s={s} b={block}"))?;
    let total = Stopwatch::start();
    let outs = execute_tupled(&art.exe, &[matrix_to_literal(a0)?])?;
    if outs.len() != 3 {
        bail!("lu_full returned {} outputs, expected 3", outs.len());
    }
    let ok = literal_to_bool(&outs[2])?;
    if !ok {
        bail!("singular matrix");
    }
    let lu = literal_to_matrix(&outs[0])?;
    let pivots: Vec<usize> = literal_to_vec_i64(&outs[1])?.into_iter().map(|v| v as usize).collect();
    let dt = total.elapsed_secs();
    Ok(LuArtifactResult { lu, pivots, step_seconds: vec![dt], total_seconds: dt })
}

// Integration tests live in rust/tests/e2e_artifacts.rs (they need the
// compiled artifacts on disk).
