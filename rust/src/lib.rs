//! # dla-codesign
//!
//! A reproduction of *"Co-Design of the Dense Linear Algebra Software Stack
//! for Multicore Processors"* (CS.DC 2023).
//!
//! The crate implements the whole stack the paper describes:
//!
//! - [`arch`] — architecture descriptions (cache geometry, SIMD, register
//!   files) with presets for the paper's two platforms (NVIDIA Carmel,
//!   AMD EPYC 7282) plus the local host.
//! - [`model`] — the analytical machinery: the micro-kernel
//!   register-pressure/flops-per-memop model, the original Low-et-al. CCP
//!   model, the paper's **refined dimension-aware model**, occupancy
//!   calculators, and the runtime [`model::selector`] that performs the
//!   paper's co-design selection per GEMM call.
//! - [`gemm`] — a native blocked GEMM engine (GotoBLAS 5-loop structure,
//!   packing, a family of micro-kernels — portable const-generic and
//!   AVX2+FMA, in f64 *and* f32 — and G3/G4 multithreading), generic
//!   over the element type ([`util::elem::Elem`]) with dtype-keyed
//!   config selection.
//! - [`lapack`] — blocked LU with partial pivoting (plus TRSM, unblocked
//!   panel factorization, row swaps and a blocked Cholesky extension) built
//!   on top of [`gemm`], exactly as the paper's Figure 2 algorithm; the
//!   [`lapack::refine`] module adds the mixed-precision solve (factor in
//!   f32, iteratively refine to f64 accuracy).
//! - [`cachesim`] + [`trace`] — a trace-driven set-associative cache
//!   hierarchy simulator and a GEMM/LU memory-trace generator; together
//!   they substitute for the paper's PMU hardware counters.
//! - [`perfmodel`] — an analytical performance model (single-core and
//!   multicore G3/G4) that turns simulated miss counts into GFLOPS curves.
//! - [`runtime`] — the persistent fork-join worker pool behind the
//!   parallel GEMM drivers, plus (behind the `pjrt` feature) a PJRT
//!   runtime that loads the AOT-compiled JAX/Pallas artifacts (HLO text)
//!   and executes them from Rust.
//! - [`coordinator`] — the serving layer: a request loop with persistent
//!   worker/workspace pools, memoized per-shape configuration selection
//!   and per-call dynamic (model-driven) dispatch.
//! - [`harness`] — regeneration code for every table and figure in the
//!   paper's evaluation section.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod arch;
pub mod bench;
pub mod cachesim;
pub mod coordinator;
pub mod gemm;
pub mod harness;
pub mod lapack;
pub mod model;
pub mod perfmodel;
pub mod runtime;
pub mod testutil;
pub mod trace;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
